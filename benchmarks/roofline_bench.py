"""Roofline summary derived from the dry-run sweep records (§Roofline).

Reads dryrun_results/ if present; prints one row per assembled cell with
the extrapolated terms.  Falls back to a note when the sweep hasn't run.
"""
from __future__ import annotations

import os


def run_all():
    rows = []
    d = os.environ.get("REPRO_DRYRUN_DIR", "dryrun_results")
    if not os.path.isdir(d):
        return [("roofline_table", 0.0, "run repro.launch.sweep_dryrun first")]
    from repro.launch.aggregate import assemble

    cells = assemble(d)
    ok = [r for r in cells if "compute_s" in r]
    for r in ok:
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}", 0.0,
            f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
            f"useful={r['useful_ratio']:.2f}"))
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        rows.append(("roofline_worst_cell", 0.0,
                     f"{worst['arch']}x{worst['shape']} "
                     f"frac={worst['roofline_fraction']:.3f}"))
    rows.append(("roofline_cells_assembled", 0.0, f"{len(ok)}/{len(cells)}"))
    return rows
