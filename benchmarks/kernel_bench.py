"""Kernel-layer benchmarks (CPU: XLA blockwise path vs naive reference —
the TPU Pallas numbers are dry-run/roofline-derived, see §Roofline).

Measures wall-time per call and, for the flash path, peak-memory proxy
(largest intermediate) derived from jax.eval_shape over the two impls.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels.ref import attention_ref, ssd_chunked_ref, ssd_sequential_ref
from repro.kernels.xla_flash import blockwise_attention


def _time(fn, *args, iters=3):
    # one warmup call (the old tuple-dispatch one-liner called fn twice
    # — or three times for tuples — before timing even started)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_flash_vs_naive():
    B, S, H, K, D = 1, 1024, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    naive = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, True, None,
                                                        0, 256))
    t_naive = _time(naive, q, k, v)
    t_flash = _time(flash, q, k, v)
    # peak intermediate: naive materialises [B,K,G,S,S] fp32
    naive_peak = B * H * S * S * 4
    flash_peak = B * H * S * 256 * 4
    return [
        ("flash_attention_xla_1k", t_flash, f"naive {t_naive:.0f}us"),
        ("flash_attention_mem_ratio", 0.0,
         f"{naive_peak / flash_peak:.0f}x smaller"),
    ]


def bench_ssd_chunked_vs_sequential():
    B, S, H, P, G, N = 1, 2048, 4, 64, 1, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    chunked = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=128))
    seq = jax.jit(lambda *a: ssd_sequential_ref(*a)[0])
    t_c = _time(chunked, x, dt, A, Bm, Cm)
    t_s = _time(seq, x, dt, A, Bm, Cm)
    return [
        ("ssd_chunked_2k", t_c, f"sequential {t_s:.0f}us "
         f"({t_s / t_c:.1f}x slower)"),
    ]


def bench_pallas_interpret_correctness_path():
    """Interpret-mode kernels (the validation path used in CI)."""
    from repro.kernels.flash_attention import flash_attention as fk
    from repro.kernels.ssd_scan import ssd_scan as sk

    B, S, H, D = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    t0 = time.perf_counter()
    fk(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    t_flash = (time.perf_counter() - t0) * 1e6
    x = jax.random.normal(ks[0], (B, S, 2, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, 2)))
    A = -jnp.ones((2,))
    Bm = jax.random.normal(ks[3], (B, S, 1, 16))
    Cm = jax.random.normal(ks[4], (B, S, 1, 16))
    t0 = time.perf_counter()
    sk(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    t_ssd = (time.perf_counter() - t0) * 1e6
    return [
        ("pallas_flash_interpret_128", t_flash, "validation path"),
        ("pallas_ssd_interpret_128", t_ssd, "validation path"),
    ]


def run_all():
    rows = []
    for fn in (bench_flash_vs_naive, bench_ssd_chunked_vs_sequential,
               bench_pallas_interpret_correctness_path):
        rows.extend(fn())
    return rows


def tune_section():
    """Autotuning dogfood sweep (ROADMAP item 3): run the repro.tune
    smoke sweeps through Experiment(engine="sim") on a seeded adversarial
    grid and record the exploration accounting.  Asserted invariants:

    * speedup >= 1.0 — the incumbent (current dispatch default) is the
      floor, a sweep can never make dispatch slower;
    * pruned > 0 — the paper's timeout/domino rule actually fired on the
      adversarial grid (pathological configs died without being run);
    * under_cap — the budget_cap sweep finished under its CostMeter cap,
      with per-config attributed costs on the records.
    """
    from repro.tune.tuner import tune

    cap = 150.0
    sweeps = []
    for kern in ("flash_attention", "ssd_scan"):
        rep = tune(kern, engine="sim", smoke=True, adversarial=4, seed=0,
                   budget_cap=cap, store=False)
        assert rep.speedup >= 1.0 - 1e-9, rep.summary()
        assert rep.pruned > 0, f"domino rule never fired: {rep.summary()}"
        assert rep.under_cap, rep.summary()
        assert any(c.get("cost") is not None for c in rep.configs), \
            "no per-config CostMeter attribution on the results table"
        sweeps.append({
            "kernel": kern, "backend": rep.backend,
            "shape_bucket": rep.shape_bucket,
            "explored": rep.explored, "measured": rep.measured,
            "timed_out": rep.timed_out, "pruned": rep.pruned,
            "pruned_fraction": round(rep.pruned_fraction, 3),
            "default_config": rep.default_config,
            "default_us": round(rep.default_us, 1),
            "best_config": rep.best_config,
            "best_us": round(rep.best_us, 1),
            "speedup": round(rep.speedup, 3),
            "budget_cap": rep.budget_cap,
            "cost_total": rep.cost_total,
            "under_cap": rep.under_cap,
        })
    return {"engine": "sim", "adversarial": 4, "seed": 0, "sweeps": sweeps}


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_kernel.json"))
    args = ap.parse_args(argv)
    payload = {
        "bench": "kernel",
        "rows": [{"name": name, "us": round(us, 1), "note": note}
                 for name, us, note in run_all()],
        "tune": tune_section(),
    }
    for row in payload["rows"]:
        print(f"{row['name']:32s} {row['us']:10.1f}us  {row['note']}")
    for sw in payload["tune"]["sweeps"]:
        print(f"tune:{sw['kernel']:27s} best={sw['best_config']} "
              f"{sw['speedup']:.2f}x | explored={sw['explored']} "
              f"pruned={sw['pruned']} timed_out={sw['timed_out']} "
              f"cost={sw['cost_total']:.2f}/{sw['budget_cap']:.0f}")
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
