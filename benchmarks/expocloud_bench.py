"""Benchmarks for the paper's four claims, on the deterministic simulator.

The ExpoCloud paper has no numeric tables — its claims are architectural:
 (1) maximal concurrency via on-the-fly instance creation,
 (2) money saved by deleting idle instances,
 (3) time+money saved by the hardness/domino mechanism,
 (4) fault tolerance keeps experiments alive at bounded overhead.
Each benchmark quantifies one claim on the B&B agent-assignment workload
(virtual clock -> exact, reproducible numbers).
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "examples")

from repro.core.experiment import Experiment
from repro.core.server import ServerConfig
from repro.core.sim import SimParams, SimTask


def _workload(n=60, spread=3.0, deadline=None):
    """Durations spread over [0.2, spread+0.2]; hardness = duration rank."""
    return [SimTask((i, 0), ("n", "id"), (i,),
                    0.2 + spread * ((i * 7) % n) / n, deadline, (i,))
            for i in range(1, n + 1)]


def _run(tasks, max_clients, use_backup=False, fail_at=None, workers=4):
    h = Experiment(tasks, engine="sim",
                   sim=SimParams(client_workers=workers),
                   config=ServerConfig(max_clients=max_clients,
                                       use_backup=use_backup,
                                       health_update_limit=3.0)).run()
    cl = h.cluster
    if fail_at is not None:
        cl.at(fail_at, lambda c: c.kill_primary())
    t0 = time.perf_counter()
    table = h.results(until=100000)
    wall_us = (time.perf_counter() - t0) * 1e6
    solved = sum(1 for _, r, _ in table.rows if r is not None)
    return {
        "makespan": cl.clock.now(),
        "cost": cl.engine.total_cost(),
        "solved": solved,
        "attempted": solved + sum(1 for _, _, s in table.rows
                                  if s == "timed_out"),
        "wall_us": wall_us,
    }


def bench_concurrency_ramp():
    """Claim 1: elastic multi-instance vs a single static instance."""
    static = _run(_workload(), max_clients=1)
    elastic = _run(_workload(), max_clients=8)
    speedup = static["makespan"] / elastic["makespan"]
    return [
        ("expocloud_makespan_static1", static["wall_us"],
         f"{static['makespan']:.1f}s"),
        ("expocloud_makespan_elastic8", elastic["wall_us"],
         f"{elastic['makespan']:.1f}s"),
        ("expocloud_concurrency_speedup", 0.0, f"{speedup:.2f}x"),
    ]


def bench_cost_saving():
    """Claim 2: BYE->delete vs paying every instance until the end."""
    r = _run(_workload(), max_clients=8)
    # counterfactual: every instance billed from t=0 to makespan
    n_instances = 8 + 1
    static_cost = n_instances * r["makespan"]
    saving = 1.0 - r["cost"] / static_cost
    return [
        ("expocloud_cost_elastic", r["wall_us"],
         f"{r['cost']:.0f} inst-s"),
        ("expocloud_cost_saving_vs_static", 0.0, f"{100*saving:.0f}%"),
    ]


def bench_domino_savings():
    """Claim 3: deadline+domino vs running everything to completion.

    Workload: half the settings are exponentially hard (would blow the
    deadline); domino should prune them after the first timeout."""
    hard = [SimTask((i, 0), ("n", "id"), (i,),
                    0.3 if i <= 20 else 50.0, 2.0, (i,))
            for i in range(1, 41)]
    with_domino = _run(hard, max_clients=4)
    no_deadline = [SimTask((i, 0), ("n", "id"), (i,),
                           0.3 if i <= 20 else 50.0, None, (i,))
                   for i in range(1, 41)]
    without = _run(no_deadline, max_clients=4)
    return [
        ("expocloud_domino_makespan", with_domino["wall_us"],
         f"{with_domino['makespan']:.1f}s vs {without['makespan']:.1f}s"),
        ("expocloud_domino_attempted", 0.0,
         f"{with_domino['attempted']}/40 vs {without['attempted']}/40"),
        ("expocloud_domino_cost_saving", 0.0,
         f"{100*(1 - with_domino['cost']/without['cost']):.0f}%"),
    ]


def bench_fault_overhead():
    """Claim 4: primary failure mid-run -> finishes; overhead vs no failure."""
    base = _run(_workload(40, 2.0), max_clients=3, use_backup=True)
    failed = _run(_workload(40, 2.0), max_clients=3, use_backup=True,
                  fail_at=6.0)
    assert failed["solved"] == 40, failed
    overhead = failed["makespan"] / base["makespan"] - 1.0
    return [
        ("expocloud_failover_makespan", failed["wall_us"],
         f"{failed['makespan']:.1f}s (+{100*overhead:.0f}% vs fault-free)"),
    ]


def bench_scheduler_throughput():
    """Framework overhead: virtual tasks scheduled per wall-second."""
    tasks = [SimTask((i, 0), ("n", "id"), (i,), 0.05, None, (i,))
             for i in range(1, 301)]
    r = _run(tasks, max_clients=4, workers=8)
    per_task_us = r["wall_us"] / 300
    return [
        ("expocloud_sched_per_task", per_task_us, "300 tasks"),
    ]


def run_all():
    rows = []
    for fn in (bench_concurrency_ramp, bench_cost_saving,
               bench_domino_savings, bench_fault_overhead,
               bench_scheduler_throughput):
        rows.extend(fn())
    return rows
