"""Scale benchmark for the discrete-event simulator core.

Sweeps the number of simulated client instances (10 -> 200), running a
fault-tolerant parameter-sweep scenario on the event-driven engine, and
records events processed, events/sec and end-to-end wall time per point.
The smallest points are also run under the legacy fixed-dt polling loop
(``SimParams(mode="fixed")``) to measure the event engine's speedup on an
identical scenario (identical final results table, asserted).

Results land in BENCH_sim.json at the repo root.

Usage:
    PYTHONPATH=src python benchmarks/sim_scale_bench.py [--smoke] [--out F]

``--smoke`` runs a reduced sweep with a hard speedup floor, for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.experiment import Experiment        # noqa: E402
from repro.core.server import ServerConfig          # noqa: E402
from repro.core.sim import InstanceType, SimParams, SimTask  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _workload(n_clients: int, tasks_per_client: int, dur_lo: float,
              dur_hi: float):
    n = n_clients * tasks_per_client
    return [SimTask((i, 0), ("n", "id"), (i,),
                    dur_lo + (dur_hi - dur_lo) * ((i * 7) % n) / n,
                    None, (i,))
            for i in range(1, n + 1)]


# Two scenario families:
#  * "chatty": short tasks, 1 Hz heartbeats — wall time is dominated by
#    real protocol messages, which both engines must pay; measures the
#    event engine's overhead floor.
#  * "long-haul": 20-60 s tasks, 5 s heartbeats — clients spend most of
#    the run silently computing, which the fixed-dt loop polls at
#    20 steps/s anyway; measures the O(events) vs O(T/dt * nodes) gap.
SCENARIOS = {
    "chatty": dict(tasks_per_client=6, dur_lo=0.3, dur_hi=3.0,
                   health_interval=1.0, health_limit=10.0),
    "long_haul": dict(tasks_per_client=4, dur_lo=20.0, dur_hi=60.0,
                      health_interval=5.0, health_limit=25.0,
                      wake_quantum=1.0),
}


def _run_once(n_clients: int, mode: str, scenario: str, spot: bool = False,
              ready_poll: bool = True):
    sc = SCENARIOS[scenario]
    params = SimParams(
        client_workers=2, mode=mode, seed=0, ready_poll=ready_poll,
        client_health_interval=sc["health_interval"],
        wake_quantum=sc.get("wake_quantum", 0.05),
        instance_types={
            # a cheaper, slower-booting preemptible tier keeps the
            # heterogeneous-type path on the hot benchmark loop
            "client": InstanceType(creation_delay=1.5,
                                   cost_per_instance_second=1.0),
        })
    h = Experiment(
        _workload(n_clients, sc["tasks_per_client"], sc["dur_lo"],
                  sc["dur_hi"]),
        engine="sim", engine_cfg={"params": params},
        config=ServerConfig(max_clients=n_clients, use_backup=False,
                            health_update_limit=sc["health_limit"]),
    ).run()
    cl = h.cluster
    if spot:
        cl.spot_wave(8.0, 0.25)
    t0 = time.perf_counter()
    table = h.results(until=1e6, max_steps=20_000_000)
    wall = time.perf_counter() - t0
    return {
        "n_clients": n_clients,
        "mode": mode,
        "scenario": scenario,
        "tasks": len(table.rows),
        "solved": sum(1 for _, r, _ in table.rows if r is not None),
        "sim_makespan_s": round(cl.clock.now(), 3),
        "wall_s": round(wall, 4),
        "events": cl.loop.processed,
        "events_per_sec": round(cl.loop.processed / wall) if wall > 0 else 0,
        "sim_s_per_wall_s": round(cl.clock.now() / wall) if wall > 0 else 0,
        "cost": round(cl.engine.total_cost(), 1),
        "cost_metered": (table.cost or {}).get("total"),
        "rows": table.rows,
    }


# ---------------------------------------------------------------------------
# ready-set polling (ROADMAP item): most of the fleet computes silently
# while a few chatty clients keep the server awake — the primary must
# drain only endpoints with pending deliveries, not sweep every client
# ---------------------------------------------------------------------------
def _mixed_workload(n_clients: int, rounds: int = 3):
    tasks = []
    i = 1
    for _ in range(n_clients * 4 * rounds):     # silent long tasks
        tasks.append(SimTask((i, 0), ("n", "id"), (i,), 40.0, None, (i,)))
        i += 1
    for _ in range(400):                        # chatty short tasks
        tasks.append(SimTask((i, 1), ("n", "id"), (0,), 0.3, None, (i,)))
        i += 1
    return tasks


def _run_ready(n_clients: int, ready_poll: bool):
    params = SimParams(client_workers=4, mode="events", seed=0,
                       ready_poll=ready_poll, client_health_interval=5.0)
    h = Experiment(
        _mixed_workload(n_clients),
        engine="sim", engine_cfg={"params": params},
        config=ServerConfig(max_clients=n_clients, use_backup=False,
                            health_update_limit=25.0),
    ).run()
    t0 = time.perf_counter()
    table = h.results(until=1e6, max_steps=20_000_000)
    return time.perf_counter() - t0, table.rows


def ready_poll_comparison(n_clients: int, repeats: int = 3) -> dict:
    """min-of-N walls for ready-set polling on vs off; identical tables
    asserted."""
    on = [_run_ready(n_clients, True) for _ in range(repeats)]
    off = [_run_ready(n_clients, False) for _ in range(repeats)]
    assert on[0][1] == off[0][1], \
        "ready-set polling changed the final results table"
    on_wall = min(w for w, _ in on)
    off_wall = min(w for w, _ in off)
    return {
        "scenario": "mixed_silent_chatty",
        "n_clients": n_clients,
        "ready_on_wall_s": round(on_wall, 4),
        "ready_off_wall_s": round(off_wall, 4),
        "speedup": round(off_wall / max(on_wall, 1e-9), 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + hard speedup floor (CI)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_sim.json"))
    args = ap.parse_args(argv)

    sweep_sizes = [10, 25] if args.smoke else [10, 25, 50, 100, 200]
    compare = ([("long_haul", 25)] if args.smoke
               else [("chatty", 10), ("chatty", 25),
                     ("long_haul", 25), ("long_haul", 50),
                     ("long_haul", 100)])

    sweep = []
    for n in sweep_sizes:
        r = _run_once(n, "events", "chatty", spot=not args.smoke)
        r.pop("rows")
        sweep.append(r)
        print(f"events mode  {n:4d} clients: wall={r['wall_s']:8.3f}s  "
              f"makespan={r['sim_makespan_s']:8.1f}s  "
              f"events={r['events']:8d}  ev/s={r['events_per_sec']:,}")

    comparisons = []
    for scenario, n in compare:
        ev = _run_once(n, "events", scenario)
        fx = _run_once(n, "fixed", scenario)
        assert ev["rows"] == fx["rows"], \
            "event and fixed engines disagree on the final results table"
        speedup = fx["wall_s"] / max(ev["wall_s"], 1e-9)
        comparisons.append({
            "scenario": scenario,
            "n_clients": n,
            "fixed_wall_s": fx["wall_s"],
            "events_wall_s": ev["wall_s"],
            "fixed_sim_s_per_wall_s": fx["sim_s_per_wall_s"],
            "events_sim_s_per_wall_s": ev["sim_s_per_wall_s"],
            "speedup": round(speedup, 1),
        })
        print(f"{scenario:9s} {n:3d} clients: fixed {fx['wall_s']:.3f}s vs "
              f"events {ev['wall_s']:.3f}s -> {speedup:.1f}x "
              f"(identical tables)")

    ready = ready_poll_comparison(50 if args.smoke else 200)
    print(f"ready-set polling {ready['n_clients']:3d} clients: "
          f"off {ready['ready_off_wall_s']:.3f}s vs "
          f"on {ready['ready_on_wall_s']:.3f}s -> {ready['speedup']:.2f}x")

    out = {
        "bench": "sim_scale",
        "sweep": sweep,
        "fixed_vs_events": comparisons,
        "ready_poll": ready,
        "max_speedup": max(c["speedup"] for c in comparisons),
    }
    if args.smoke and out["max_speedup"] < 5.0:
        # wall-clock noise on shared CI runners can dent a single
        # measurement: retry once before declaring a regression, and
        # record the retry in the artifact
        scenario, n = compare[0]
        ev = _run_once(n, "events", scenario)
        fx = _run_once(n, "fixed", scenario)
        retry = round(fx["wall_s"] / max(ev["wall_s"], 1e-9), 1)
        out["smoke_retry_speedup"] = retry
        out["max_speedup"] = max(out["max_speedup"], retry)
    if args.smoke and out["ready_poll"]["speedup"] < 1.0:
        # noisy-runner retry, recorded in the artifact
        out["ready_poll_retry"] = ready_poll_comparison(50)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")

    if args.smoke:
        # sim-speed regression tripwire: the event engine must stay far
        # ahead of the fixed-dt loop on the same scenario
        assert out["max_speedup"] >= 5.0, out["fixed_vs_events"]
        assert all(r["solved"] == r["tasks"] for r in sweep), sweep
        # ready-set polling must never cost wall time (it wins ~1.2-1.3x
        # on quiet fleets; noisy runners got one retry above)
        best_ready = max(out["ready_poll"]["speedup"],
                         out.get("ready_poll_retry", {}).get("speedup", 0.0))
        assert best_ready >= 1.0, \
            (out["ready_poll"], out.get("ready_poll_retry"))
    return out


if __name__ == "__main__":
    main()
