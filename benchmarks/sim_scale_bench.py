"""Scale benchmark for the discrete-event simulator core.

Sweeps the number of simulated client instances (10 -> 200), running a
fault-tolerant parameter-sweep scenario on the event-driven engine, and
records events processed, events/sec and end-to-end wall time per point.
The smallest points are also run under the legacy fixed-dt polling loop
(``SimParams(mode="fixed")``) to measure the event engine's speedup on an
identical scenario (identical final results table, asserted).

The fleet section scales to 10,000 clients / 60,000 task cells and runs
the same scenario sharded (``Experiment(..., shards=8)``) and under a
single scheduler, asserting that both runs solve/prune every task exactly
once with identical solved and pruned∪timed-out sets, and that the
sharded run sustains ``FLEET_FLOOR`` aggregate events/sec in its steady
window (floor asserted in ``--smoke``).

Results land in BENCH_sim.json at the repo root.

Usage:
    PYTHONPATH=src python benchmarks/sim_scale_bench.py [--smoke] [--out F]

``--smoke`` runs a reduced sweep with hard floors, for CI.
"""
from __future__ import annotations

import argparse
import contextlib
import gc
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.experiment import Experiment        # noqa: E402
from repro.core.scheduler import DONE, PRUNED, TIMED_OUT  # noqa: E402
from repro.core.server import ServerConfig          # noqa: E402
from repro.core.sim import InstanceType, SimParams, SimTask  # noqa: E402
from repro.tune.measure import retry_measurement      # noqa: E402,F401
# retry_measurement moved to repro.tune.measure (shared with the kernel
# autotuner); re-exported here because serve_bench imports it from us.

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _workload(n_clients: int, tasks_per_client: int, dur_lo: float,
              dur_hi: float):
    n = n_clients * tasks_per_client
    return [SimTask((i, 0), ("n", "id"), (i,),
                    dur_lo + (dur_hi - dur_lo) * ((i * 7) % n) / n,
                    None, (i,))
            for i in range(1, n + 1)]


# Two scenario families:
#  * "chatty": short tasks, 1 Hz heartbeats — wall time is dominated by
#    real protocol messages, which both engines must pay; measures the
#    event engine's overhead floor.
#  * "long-haul": 20-60 s tasks, 5 s heartbeats — clients spend most of
#    the run silently computing, which the fixed-dt loop polls at
#    20 steps/s anyway; measures the O(events) vs O(T/dt * nodes) gap.
SCENARIOS = {
    "chatty": dict(tasks_per_client=6, dur_lo=0.3, dur_hi=3.0,
                   health_interval=1.0, health_limit=10.0),
    "long_haul": dict(tasks_per_client=4, dur_lo=20.0, dur_hi=60.0,
                      health_interval=5.0, health_limit=25.0,
                      wake_quantum=1.0),
}


def _run_once(n_clients: int, mode: str, scenario: str, spot: bool = False,
              ready_poll: bool = True):
    sc = SCENARIOS[scenario]
    params = SimParams(
        client_workers=2, mode=mode, seed=0, ready_poll=ready_poll,
        client_health_interval=sc["health_interval"],
        wake_quantum=sc.get("wake_quantum", 0.05),
        instance_types={
            # a cheaper, slower-booting preemptible tier keeps the
            # heterogeneous-type path on the hot benchmark loop
            "client": InstanceType(creation_delay=1.5,
                                   cost_per_instance_second=1.0),
        })
    h = Experiment(
        _workload(n_clients, sc["tasks_per_client"], sc["dur_lo"],
                  sc["dur_hi"]),
        engine="sim", engine_cfg={"params": params},
        config=ServerConfig(max_clients=n_clients, use_backup=False,
                            health_update_limit=sc["health_limit"]),
    ).run()
    cl = h.cluster
    if spot:
        cl.spot_wave(8.0, 0.25)
    t0 = time.perf_counter()
    table = h.results(until=1e6, max_steps=20_000_000)
    wall = time.perf_counter() - t0
    return {
        "n_clients": n_clients,
        "mode": mode,
        "scenario": scenario,
        "tasks": len(table.rows),
        "solved": sum(1 for _, r, _ in table.rows if r is not None),
        "sim_makespan_s": round(cl.clock.now(), 3),
        "wall_s": round(wall, 4),
        "events": cl.loop.processed,
        "events_per_sec": round(cl.loop.processed / wall) if wall > 0 else 0,
        "sim_s_per_wall_s": round(cl.clock.now() / wall) if wall > 0 else 0,
        "cost": round(cl.engine.total_cost(), 1),
        "cost_metered": (table.cost or {}).get("total"),
        "rows": table.rows,
    }


# ---------------------------------------------------------------------------
# ready-set polling (ROADMAP item): most of the fleet computes silently
# while a few chatty clients keep the server awake — the primary must
# drain only endpoints with pending deliveries, not sweep every client
# ---------------------------------------------------------------------------
def _mixed_workload(n_clients: int, rounds: int = 3):
    tasks = []
    i = 1
    for _ in range(n_clients * 4 * rounds):     # silent long tasks
        tasks.append(SimTask((i, 0), ("n", "id"), (i,), 40.0, None, (i,)))
        i += 1
    for _ in range(400):                        # chatty short tasks
        tasks.append(SimTask((i, 1), ("n", "id"), (0,), 0.3, None, (i,)))
        i += 1
    return tasks


def _run_ready(n_clients: int, ready_poll: bool):
    params = SimParams(client_workers=4, mode="events", seed=0,
                       ready_poll=ready_poll, client_health_interval=5.0)
    h = Experiment(
        _mixed_workload(n_clients),
        engine="sim", engine_cfg={"params": params},
        config=ServerConfig(max_clients=n_clients, use_backup=False,
                            health_update_limit=25.0),
    ).run()
    t0 = time.perf_counter()
    table = h.results(until=1e6, max_steps=20_000_000)
    return time.perf_counter() - t0, table.rows


def ready_poll_comparison(n_clients: int, repeats: int = 3) -> dict:
    """min-of-N walls for ready-set polling on vs off; identical tables
    asserted."""
    on = [_run_ready(n_clients, True) for _ in range(repeats)]
    off = [_run_ready(n_clients, False) for _ in range(repeats)]
    assert on[0][1] == off[0][1], \
        "ready-set polling changed the final results table"
    on_wall = min(w for w, _ in on)
    off_wall = min(w for w, _ in off)
    return {
        "scenario": "mixed_silent_chatty",
        "n_clients": n_clients,
        "ready_on_wall_s": round(on_wall, 4),
        "ready_off_wall_s": round(off_wall, 4),
        "speedup": round(off_wall / max(on_wall, 1e-9), 2),
    }


# ---------------------------------------------------------------------------
# fleet scale: 10k clients / 60k task cells, sharded vs single-scheduler.
#
# The aggregate throughput metric counts, summed across shards:
#   * event-loop events processed,
#   * wire messages sent, and
#   * logical scheduling events (grants, report ACKs, results, hardness
#     reports, log entries, domino deliveries) — counted per *item* by
#     the scheduler cores, so the metric is invariant to transport
#     batching: coalescing messages drives wall time down without
#     deflating the numerator.
# The run is split at FLEET_BOOT_T into a boot window (fleet spin-up:
# instance creation delays, handshakes, first grants) and the steady
# window where the scheduling planes are saturated; the ≥FLEET_FLOOR
# floor is asserted on the steady window ("sustains", not "peaks").
# ---------------------------------------------------------------------------
FLEET_NA, FLEET_NB = 300, 200            # 60,000 task cells
FLEET_BASE, FLEET_DEADLINE = 0.05, 1.2
FLEET_CLIENTS = 10_000
FLEET_SHARDS = 8
FLEET_BOOT_T = 0.55                      # creation_delay 0.5 + margin
FLEET_FLOOR = 200_000                    # aggregate events/sec, steady


def _fleet_grid():
    # duration is a quantized step function of (a, b): cells with
    # duration > FLEET_DEADLINE time out and domino-prune their
    # dominated peers; the rest solve.  Hardness (a*b) is monotone
    # enough for contiguous-hardness sharding to split the frontier.
    return [SimTask((a, b), ("a", "b"), (a, b),
                    FLEET_BASE * (a // 10 + b // 50 + 1), FLEET_DEADLINE,
                    (a * b,))
            for a in range(FLEET_NA) for b in range(FLEET_NB)]


@contextlib.contextmanager
def _gc_paused():
    """Freeze the object graph and disable collection for the measured
    run: generational GC sweeps over the ~10^6 live simulation objects
    otherwise dominate wall time (observed 30-40%) and add most of the
    run-to-run noise."""
    gc.collect()
    gc.freeze()
    gc.disable()
    try:
        yield
    finally:
        gc.enable()
        gc.unfreeze()
        gc.collect()


def _fleet_cores(cluster):
    if hasattr(cluster, "engines"):      # ShardedSimCluster
        return cluster.engines, [srv.core for srv in cluster.servers]
    return [cluster.engine], [cluster.server.core]


def _fleet_counters(cluster):
    engines, cores = _fleet_cores(cluster)
    ev = cluster.loop.processed
    msgs = sum(e.network.messages_sent for e in engines)
    stats: dict[str, int] = {}
    for core in cores:
        for k, v in core.stats.items():
            stats[k] = stats.get(k, 0) + v
    return ev, msgs, stats


def _fleet_status_sets(cluster):
    """(solved, unsolved, nonterminal) sets of task parameter tuples —
    the global task identity across shard-local tid spaces."""
    solved, unsolved, nonterminal = set(), set(), set()
    for core in _fleet_cores(cluster)[1]:
        for tid, st in enumerate(core.status):
            key = core.tasks[tid].parameters()
            if st == DONE:
                solved.add(key)
            elif st in (PRUNED, TIMED_OUT):
                unsolved.add(key)
            else:
                nonterminal.add(key)
    return solved, unsolved, nonterminal


def _fleet_window(ev, msgs, stats, wall):
    logical = sum(stats.values())
    total = ev + msgs + logical
    return {
        "wall_s": round(wall, 4),
        "loop_events": ev,
        "wire_messages": msgs,
        "logical_events": logical,
        "events_per_sec": round(total / wall) if wall > 0 else 0,
    }


def run_fleet(shards: int):
    """One fleet run; returns (record, (solved, unsolved, nonterminal))."""
    n_per_shard = FLEET_CLIENTS // shards
    params = SimParams(
        client_workers=6, mode="events", seed=0, ready_poll=True,
        min_create_interval=0.0, client_health_interval=1e6,
        wake_quantum=0.05,
        instance_types={"client": InstanceType(
            creation_delay=0.5, cost_per_instance_second=1.0)})
    config = ServerConfig(
        max_clients=n_per_shard, use_backup=False,
        health_update_limit=1e9, health_interval=1e6,
        instance_max_non_active_time=1e9, create_batch=n_per_shard)
    h = Experiment(_fleet_grid(), engine="sim", shards=shards,
                   engine_cfg={"params": params}, config=config).run()
    cl = h.cluster
    with _gc_paused():
        t0 = time.perf_counter()
        while True:                      # boot: drive up to FLEET_BOOT_T
            nt = cl.loop.next_time()
            if nt is None or nt >= FLEET_BOOT_T:
                break
            cl.step()
        t1 = time.perf_counter()
        b_ev, b_msgs, b_stats = _fleet_counters(cl)
        cl.run(until=1e6, max_steps=20_000_000)
        t2 = time.perf_counter()
    ev, msgs, stats = _fleet_counters(cl)
    n_rows = (len(cl.merged_results().rows) if hasattr(cl, "engines")
              else len(h.shard_servers[0].final_results.rows))
    sets = _fleet_status_sets(cl)
    s_stats = {k: stats[k] - b_stats.get(k, 0) for k in stats}
    record = {
        "scenario": "fleet",
        "shards": shards,
        "n_clients": FLEET_CLIENTS,
        "tasks": FLEET_NA * FLEET_NB,
        "rows": n_rows,
        "solved": len(sets[0]),
        "pruned_or_timed_out": len(sets[1]),
        "sim_makespan_s": round(cl.clock.now(), 3),
        "boot": _fleet_window(b_ev, b_msgs, b_stats, t1 - t0),
        "steady": _fleet_window(ev - b_ev, msgs - b_msgs, s_stats, t2 - t1),
        "total": _fleet_window(ev, msgs, stats, t2 - t0),
        "logical_stats_steady": s_stats,
    }
    return record, sets


def fleet_comparison(out: dict, smoke: bool) -> dict:
    """Sharded (K=FLEET_SHARDS) vs single-scheduler fleet run: asserts
    exactly-once terminal status and identical solved / pruned sets, and
    (in smoke) holds the sharded steady window to the throughput floor
    with a noisy-runner retry."""
    single, single_sets = run_fleet(1)
    sharded, sharded_sets = run_fleet(FLEET_SHARDS)

    def check(rec, sets, other_sets=None):
        solved, unsolved, nonterminal = sets
        assert not nonterminal, \
            f"{len(nonterminal)} tasks ended non-terminal ({rec['shards']}" \
            f" shards)"
        assert not (solved & unsolved), "a task is both solved and pruned"
        assert len(solved) + len(unsolved) == rec["tasks"], \
            "task lost: terminal statuses do not cover the grid"
        assert rec["rows"] == rec["tasks"], \
            f"results table has {rec['rows']} rows for {rec['tasks']} tasks"
        if other_sets is not None:
            assert solved == other_sets[0], \
                "sharded and single-scheduler solved sets differ"
            assert unsolved == other_sets[1], \
                "sharded and single-scheduler pruned sets differ"
        return rec

    check(single, single_sets)
    check(sharded, sharded_sets, single_sets)

    def measure():
        rec, sets = run_fleet(FLEET_SHARDS)
        return check(rec, sets, single_sets)

    if smoke:
        sharded = retry_measurement(
            out, "fleet_floor", sharded, measure,
            lambda r: r["steady"]["events_per_sec"] >= FLEET_FLOOR,
            lambda a, b: (b if b["steady"]["events_per_sec"]
                          > a["steady"]["events_per_sec"] else a),
            retries=2)
    for rec in (single, sharded):
        print(f"fleet {rec['shards']:2d} shard(s): "
              f"boot {rec['boot']['wall_s']:.2f}s, "
              f"steady {rec['steady']['wall_s']:.2f}s "
              f"@ {rec['steady']['events_per_sec']:,} ev/s, "
              f"solved={rec['solved']} "
              f"pruned/timed-out={rec['pruned_or_timed_out']}")
    return {"floor_events_per_sec": FLEET_FLOOR,
            "single": single, "sharded": sharded}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + hard speedup floor (CI)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_sim.json"))
    args = ap.parse_args(argv)

    sweep_sizes = [10, 25] if args.smoke else [10, 25, 50, 100, 200]
    compare = ([("long_haul", 25)] if args.smoke
               else [("chatty", 10), ("chatty", 25),
                     ("long_haul", 25), ("long_haul", 50),
                     ("long_haul", 100)])

    sweep = []
    for n in sweep_sizes:
        r = _run_once(n, "events", "chatty", spot=not args.smoke)
        r.pop("rows")
        sweep.append(r)
        print(f"events mode  {n:4d} clients: wall={r['wall_s']:8.3f}s  "
              f"makespan={r['sim_makespan_s']:8.1f}s  "
              f"events={r['events']:8d}  ev/s={r['events_per_sec']:,}")

    comparisons = []
    for scenario, n in compare:
        ev = _run_once(n, "events", scenario)
        fx = _run_once(n, "fixed", scenario)
        assert ev["rows"] == fx["rows"], \
            "event and fixed engines disagree on the final results table"
        speedup = fx["wall_s"] / max(ev["wall_s"], 1e-9)
        comparisons.append({
            "scenario": scenario,
            "n_clients": n,
            "fixed_wall_s": fx["wall_s"],
            "events_wall_s": ev["wall_s"],
            "fixed_sim_s_per_wall_s": fx["sim_s_per_wall_s"],
            "events_sim_s_per_wall_s": ev["sim_s_per_wall_s"],
            "speedup": round(speedup, 1),
        })
        print(f"{scenario:9s} {n:3d} clients: fixed {fx['wall_s']:.3f}s vs "
              f"events {ev['wall_s']:.3f}s -> {speedup:.1f}x "
              f"(identical tables)")

    ready = ready_poll_comparison(50 if args.smoke else 200)
    print(f"ready-set polling {ready['n_clients']:3d} clients: "
          f"off {ready['ready_off_wall_s']:.3f}s vs "
          f"on {ready['ready_on_wall_s']:.3f}s -> {ready['speedup']:.2f}x")

    out = {
        "bench": "sim_scale",
        "sweep": sweep,
        "fixed_vs_events": comparisons,
        "ready_poll": ready,
        "max_speedup": max(c["speedup"] for c in comparisons),
    }
    if args.smoke:
        # wall-clock noise on shared CI runners can dent a single
        # measurement: retry before declaring a regression, with every
        # repeat recorded under out["retries"]
        def _measure_speedup():
            scenario, n = compare[0]
            ev = _run_once(n, "events", scenario)
            fx = _run_once(n, "fixed", scenario)
            return round(fx["wall_s"] / max(ev["wall_s"], 1e-9), 1)

        out["max_speedup"] = retry_measurement(
            out, "max_speedup", out["max_speedup"], _measure_speedup,
            lambda s: s >= 5.0, max)
        out["ready_poll"] = retry_measurement(
            out, "ready_poll", ready, lambda: ready_poll_comparison(50),
            lambda r: r["speedup"] >= 1.0,
            lambda a, b: b if b["speedup"] > a["speedup"] else a)

    out["fleet"] = fleet_comparison(out, smoke=args.smoke)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")

    if args.smoke:
        # sim-speed regression tripwire: the event engine must stay far
        # ahead of the fixed-dt loop on the same scenario
        assert out["max_speedup"] >= 5.0, out["fixed_vs_events"]
        assert all(r["solved"] == r["tasks"] for r in sweep), sweep
        # ready-set polling must never cost wall time (it wins ~1.2-1.3x
        # on quiet fleets; noisy runners got retries above)
        assert out["ready_poll"]["speedup"] >= 1.0, \
            (out["ready_poll"], out.get("retries"))
        # fleet floor: the sharded 10k-client scenario must sustain the
        # aggregate throughput floor in its steady window
        assert (out["fleet"]["sharded"]["steady"]["events_per_sec"]
                >= FLEET_FLOOR), \
            (out["fleet"]["sharded"]["steady"], out.get("retries"))
    return out


if __name__ == "__main__":
    main()
