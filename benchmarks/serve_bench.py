"""Serving fast-path benchmark: fused on-device decode loop vs per-step
host sync, sequential-force vs chunked prefill, and the decode-attention
kernel, under a seeded Poisson many-user request trace.

Sections (all on a CPU-sized 2-layer config so dispatch/host-sync overhead
— the thing the fused loop removes — dominates over model compute):

* ``throughput``: same greedy workload through ``mode="host"`` (the seed
  engine's per-step-host-sync cost profile: one decode dispatch, a full
  logits device->host transfer and per-slot python sampling per token)
  and ``mode="fused"`` (sampling + slot bookkeeping inside one jitted
  ``lax.scan``, one host sync per ``steps_per_sync`` steps).  Batched
  greedy outputs are asserted byte-identical to each request decoded
  alone, sequentially (continuous-batching invariance — slot contents
  never leak across slots); ``--smoke`` asserts the >= 5x tokens/sec
  floor through ``retry_measurement``.  Host-vs-fused outputs are *not*
  byte-compared: they are different XLA programs, and XLA does not
  guarantee bitwise-identical bf16 logits across program boundaries, so
  near-tie argmax rows may legitimately flip.
* ``prefill``: long prompts via sequential one-token-per-step forcing vs
  ``prefill_chunk`` batched admission (identical outputs asserted),
  recording decode steps, wall time and time-to-first-token.
* ``poisson_trace``: wall-clock replay of a seeded Poisson arrival trace
  with mixed prompt/output lengths; tokens/sec and p50/p99 inter-token
  gaps.  The fused engine observes tokens in ``steps_per_sync`` bursts,
  so its p99 gap reflects sync quantisation — the artifact records it
  rather than hiding it.
* ``decode_kernel``: the Sq=1 Pallas decode kernel (interpret mode)
  against the pure-jnp reference on a ragged GQA batch with non-dividing
  Sk, plus XLA-path timing.
* ``memory``: paged vs dense KV under the *same* HBM budget.  The dense
  engine's ``slots x max_seq`` KV bytes buy exactly ``slots x
  ceil(max_seq/page_size)`` pool pages; with a short-request mix the
  paged engine runs >= 4x the concurrent slots in that budget
  (``peak_occupied`` asserted), at >= 0.9x the fused-dense tokens/sec on
  the equal-slots workload (floor via ``retry_measurement`` under
  ``--smoke``).  An ``admission_scaling`` subsection reruns a hybrid
  (attention+SSM) config at two ``max_seq`` values and asserts the
  ``admit_cache_elems`` counter scales with ``max_seq`` for dense but
  stays flat for paged — admission no longer round-trips KV stripes.

Results land in BENCH_serve.json at the repo root.

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax                                           # noqa: E402
import numpy as np                                   # noqa: E402

from sim_scale_bench import retry_measurement        # noqa: E402

from repro.configs import reduced_config             # noqa: E402
from repro.configs.registry import with_segment_counts  # noqa: E402
from repro.models import lm                          # noqa: E402
from repro.models.params import init_params, is_param  # noqa: E402
from repro.serve.engine import DecodeEngine, Request  # noqa: E402
from repro.serve.trace import poisson_trace          # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARCH = "smollm-360m"
MAX_SEQ = 128
SLOTS = 4


def _cfg_params():
    cfg = with_segment_counts(reduced_config(ARCH), [2])
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _workload(cfg, n, *, seed=7, plen=(5, 12), max_new=16):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        L = int(rng.integers(plen[0], plen[1] + 1))
        out.append((rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    max_new))
    return out


def _run(cfg, params, work, **engine_kw):
    eng = DecodeEngine(cfg, params, max_seq=MAX_SEQ, **engine_kw)
    reqs = [Request(prompt=p, max_new_tokens=m) for p, m in work]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    steps = eng.run_until_drained()
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    outputs = [[int(np.asarray(t)) for t in r.output] for r in reqs]
    return {"tokens": toks, "steps": steps, "wall_s": round(wall, 4),
            "tok_s": round(toks / wall, 2)}, outputs


def _warmup(cfg, params, *, plen=(5, 12), **engine_kw):
    # prompts must be long enough to exercise every program the timed run
    # will hit (e.g. a full prefill chunk), or compilation lands in-region
    _run(cfg, params, _workload(cfg, 2, seed=1, plen=plen, max_new=3),
         **engine_kw)


# ---------------------------------------------------------------------------
# throughput: host-sync-per-step vs fused loop
# ---------------------------------------------------------------------------
def bench_throughput(out, cfg, params, *, smoke: bool):
    n = 12 if smoke else 24
    work = _workload(cfg, n, plen=(4, 8), max_new=24)
    _warmup(cfg, params, mode="host", batch_slots=SLOTS)
    _warmup(cfg, params, mode="fused", batch_slots=SLOTS, steps_per_sync=16)

    def measure():
        host, _ = _run(cfg, params, work, mode="host", batch_slots=SLOTS)
        fused, out_f = _run(cfg, params, work, mode="fused",
                            batch_slots=SLOTS, steps_per_sync=16)
        return {"host": host, "fused": fused,
                "speedup": round(fused["tok_s"] / host["tok_s"], 2),
                "outputs": out_f}

    rec = measure()
    if smoke:
        rec = retry_measurement(
            out, "fused_speedup", rec, measure,
            accept=lambda r: r["speedup"] >= 5.0,
            best=lambda a, b: a if a["speedup"] >= b["speedup"] else b,
            retries=2)
        assert rec["speedup"] >= 5.0, \
            f"fused loop speedup {rec['speedup']}x < 5x floor"

    # continuous-batching invariance: batched greedy == each request decoded
    # alone, one after another, through the same fused program (same engine
    # geometry, so slot isolation is the only thing under test — not
    # cross-program fp reproducibility, which XLA does not promise)
    solo = []
    for p, m in work:
        _, o = _run(cfg, params, [(p, m)], mode="fused",
                    batch_slots=SLOTS, steps_per_sync=16)
        solo.append(o[0])
    assert rec.pop("outputs") == solo, \
        "batched greedy outputs != single-request sequential decode"
    rec["solo_identity"] = True
    out["throughput"] = rec
    print(f"[throughput] host {rec['host']['tok_s']} tok/s, "
          f"fused {rec['fused']['tok_s']} tok/s "
          f"({rec['speedup']}x, identity ok)")


# ---------------------------------------------------------------------------
# prefill: sequential forcing vs chunked admission
# ---------------------------------------------------------------------------
def _run_ttft(cfg, params, work, **engine_kw):
    """Like _run but records time-to-first-token per request."""
    eng = DecodeEngine(cfg, params, max_seq=MAX_SEQ, **engine_kw)
    reqs = [Request(prompt=p, max_new_tokens=m) for p, m in work]
    for r in reqs:
        eng.submit(r)
    ttft = [None] * len(reqs)
    t0 = time.perf_counter()
    while (eng.queue or any(s is not None for s in eng.slot_req)) \
            and eng.steps < 100_000:
        eng.step()
        now = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            if ttft[i] is None and r.output:
                ttft[i] = now
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    outputs = [[int(np.asarray(t)) for t in r.output] for r in reqs]
    return {"tokens": toks, "steps": eng.steps, "wall_s": round(wall, 4),
            "tok_s": round(toks / wall, 2),
            "ttft_mean_s": round(float(np.mean(ttft)), 4)}, outputs


def bench_prefill(out, cfg, params, *, smoke: bool):
    n = 6 if smoke else 12
    work = _workload(cfg, n, seed=11, plen=(36, 56), max_new=4)
    chunk_kw = dict(prefill_chunk=16, max_prefill_tokens_per_sync=32)
    _warmup(cfg, params, plen=(36, 56), mode="fused", batch_slots=SLOTS)
    _warmup(cfg, params, plen=(36, 56), mode="fused", batch_slots=SLOTS,
            **chunk_kw)
    seq, out_s = _run_ttft(cfg, params, work, mode="fused",
                           batch_slots=SLOTS)
    chunked, out_c = _run_ttft(cfg, params, work, mode="fused",
                               batch_slots=SLOTS, **chunk_kw)
    assert out_s == out_c, "chunked prefill changed greedy outputs"
    assert chunked["steps"] < seq["steps"], \
        "chunked prefill should need fewer decode steps"
    out["prefill"] = {"sequential_force": seq, "chunked": chunked,
                      "chunk": 16, "identity": True}
    print(f"[prefill] sequential {seq['steps']} steps / {seq['wall_s']}s, "
          f"chunked {chunked['steps']} steps / {chunked['wall_s']}s")


# ---------------------------------------------------------------------------
# poisson trace replay
# ---------------------------------------------------------------------------
def _replay(cfg, params, trace, **engine_kw):
    eng = DecodeEngine(cfg, params, max_seq=MAX_SEQ, **engine_kw)
    reqs = [Request(prompt=t.prompt, max_new_tokens=t.max_new_tokens,
                    temperature=t.temperature) for t in trace]
    stamps: list[list[float]] = [[] for _ in reqs]   # arrival + per-token
    nxt = 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while nxt < len(reqs) and trace[nxt].arrival_s <= now:
            eng.submit(reqs[nxt])
            stamps[nxt].append(now)
            nxt += 1
        busy = eng.queue or any(s is not None for s in eng.slot_req)
        if not busy:
            if nxt >= len(reqs):
                break
            time.sleep(min(trace[nxt].arrival_s - now, 0.005))
            continue
        eng.step()
        now = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            while len(stamps[i]) - 1 < len(r.output):
                stamps[i].append(now)
    wall = time.perf_counter() - t0
    gaps = np.concatenate([np.diff(s) for s in stamps if len(s) > 1])
    toks = sum(len(r.output) for r in reqs)
    return {"tokens": toks, "wall_s": round(wall, 3),
            "tok_s": round(toks / wall, 2),
            "gap_p50_ms": round(float(np.percentile(gaps, 50)) * 1e3, 3),
            "gap_p99_ms": round(float(np.percentile(gaps, 99)) * 1e3, 3)}


def bench_poisson(out, cfg, params, *, smoke: bool):
    n = 16 if smoke else 48
    trace = poisson_trace(n_requests=n, rate_per_s=40.0,
                          vocab_size=cfg.vocab_size, seed=3,
                          prompt_lens=(4, 16), output_lens=(4, 12))
    _warmup(cfg, params, mode="host", batch_slots=SLOTS)
    _warmup(cfg, params, mode="fused", batch_slots=SLOTS, steps_per_sync=8)
    out["poisson_trace"] = {
        "requests": n, "rate_per_s": 40.0,
        "host": _replay(cfg, params, trace, mode="host", batch_slots=SLOTS),
        "fused": _replay(cfg, params, trace, mode="fused",
                         batch_slots=SLOTS, steps_per_sync=8),
        "note": "fused p99 gap includes steps_per_sync burst quantisation",
    }
    h, f = out["poisson_trace"]["host"], out["poisson_trace"]["fused"]
    print(f"[poisson] host {h['tok_s']} tok/s p99 {h['gap_p99_ms']}ms; "
          f"fused {f['tok_s']} tok/s p99 {f['gap_p99_ms']}ms")


# ---------------------------------------------------------------------------
# decode-attention kernel
# ---------------------------------------------------------------------------
def bench_decode_kernel(out, *, smoke: bool):
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.ref import decode_attention_ref

    B, S, H, K, D = 4, 100, 8, 2, 32          # non-dividing Sk, GQA 4:1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, D), jax.numpy.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jax.numpy.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jax.numpy.float32)
    kv_len = jax.numpy.asarray([7, 31, 64, 100], jax.numpy.int32)
    got = decode_attention(q, k, v, kv_len, block_k=32, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    diff = float(jax.numpy.max(jax.numpy.abs(got - ref)))
    assert diff < 2e-5, f"decode kernel vs ref diff {diff}"

    ref_jit = jax.jit(decode_attention_ref)
    ref_jit(q, k, v, kv_len).block_until_ready()
    reps = 20 if smoke else 100
    t0 = time.perf_counter()
    for _ in range(reps):
        ref_jit(q, k, v, kv_len).block_until_ready()
    ref_ms = (time.perf_counter() - t0) / reps * 1e3
    out["decode_kernel"] = {
        "shape": {"B": B, "Sk": S, "H": H, "kv_heads": K, "head_dim": D},
        "kv_len": [int(x) for x in kv_len],
        "max_abs_diff_vs_ref": diff,
        "xla_ref_ms": round(ref_ms, 3),
        "note": "Pallas kernel validated in interpret mode on this "
                "container; compiled path targets TPU",
    }
    print(f"[decode_kernel] interpret vs ref diff {diff:.2e}, "
          f"xla ref {ref_ms:.2f}ms")


# ---------------------------------------------------------------------------
# memory: paged vs dense KV in the same HBM budget
# ---------------------------------------------------------------------------
def _kv_bytes(cfg, *, slots, max_seq, paged=None):
    """KV bytes from the cache descriptor tree (leaves with a seq_kv axis)."""
    descr = jax.tree_util.tree_leaves(
        lm.make_cache(cfg, slots, max_seq, paged=paged), is_leaf=is_param)
    return sum(int(np.prod(p.shape)) * np.dtype(p.dtype).itemsize
               for p in descr if "seq_kv" in p.logical)


def _run_eng(cfg, params, work, **engine_kw):
    eng = DecodeEngine(cfg, params, max_seq=MAX_SEQ, **engine_kw)
    reqs = [Request(prompt=p, max_new_tokens=m) for p, m in work]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    assert all(r.done and not r.failed for r in reqs)
    return {"tokens": toks, "wall_s": round(wall, 4),
            "tok_s": round(toks / wall, 2)}, eng


def bench_memory(out, cfg, params, *, smoke: bool):
    ps = 16
    width = -(-MAX_SEQ // ps)
    budget_pages = SLOTS * width      # pool bytes == dense slots x max_seq
    paged_slots = SLOTS * 4
    dense_bytes = _kv_bytes(cfg, slots=SLOTS, max_seq=MAX_SEQ)
    paged_bytes = _kv_bytes(cfg, slots=paged_slots, max_seq=MAX_SEQ,
                            paged=(budget_pages, ps))
    assert paged_bytes <= dense_bytes, (paged_bytes, dense_bytes)

    # short-request mix: prompt+output fit in one page, so the pool admits
    # 4x the dense slot count concurrently inside the same byte budget
    paged_kw = dict(mode="fused", batch_slots=paged_slots, steps_per_sync=4,
                    kv_layout="paged", page_size=ps, num_pages=budget_pages)
    n = paged_slots + 8
    work = _workload(cfg, n, seed=13, plen=(4, 6), max_new=8)
    _warmup(cfg, params, plen=(4, 6), **paged_kw)
    conc, eng = _run_eng(cfg, params, work, **paged_kw)
    ks = eng.kv_stats()
    assert ks["peak_occupied"] >= 4 * SLOTS, \
        f"paged held {ks['peak_occupied']} concurrent slots in the dense " \
        f"budget, expected >= {4 * SLOTS}"

    # throughput parity at equal slot count: paging indirection must not
    # tax the fused decode loop by more than 10% on the CPU smoke config
    par_work = _workload(cfg, 12 if smoke else 24, plen=(4, 8), max_new=24)
    par_dense = dict(mode="fused", batch_slots=SLOTS, steps_per_sync=16)
    par_paged = dict(par_dense, kv_layout="paged", page_size=ps,
                     num_pages=budget_pages)
    _warmup(cfg, params, **par_dense)
    _warmup(cfg, params, **par_paged)

    def measure():
        # best-of-3 per side: single CPU runs jitter ~10%, which would
        # swamp the <10% tax the floor is meant to police
        d = max((_run_eng(cfg, params, par_work, **par_dense)[0]
                 for _ in range(3)), key=lambda r: r["tok_s"])
        p = max((_run_eng(cfg, params, par_work, **par_paged)[0]
                 for _ in range(3)), key=lambda r: r["tok_s"])
        return {"dense": d, "paged": p,
                "ratio": round(p["tok_s"] / d["tok_s"], 3)}

    parity = measure()
    if smoke:
        parity = retry_measurement(
            out, "paged_parity", parity, measure,
            accept=lambda r: r["ratio"] >= 0.9,
            best=lambda a, b: a if a["ratio"] >= b["ratio"] else b,
            retries=2)
        assert parity["ratio"] >= 0.9, \
            f"paged throughput {parity['ratio']}x dense < 0.9x floor"

    out["memory"] = {
        "page_size": ps, "num_pages": budget_pages,
        "dense_kv_bytes": dense_bytes, "paged_kv_bytes": paged_bytes,
        "dense_slots": SLOTS, "paged_slots": paged_slots,
        "peak_occupied": ks["peak_occupied"],
        "high_water_pages": ks["high_water"],
        "preemptions": ks["preemptions"],
        "concurrency": conc, "throughput_parity": parity,
        "admission_scaling": _admission_scaling(),
    }
    print(f"[memory] {ks['peak_occupied']} concurrent slots in the "
          f"{dense_bytes >> 10}KiB dense budget ({SLOTS} dense slots), "
          f"parity {parity['ratio']}x")


def _admission_scaling():
    """Hybrid (attention+SSM) admission cost: dense round-trips the whole
    cache per admission (scales with max_seq); paged touches O(1) state
    plus the pages actually allocated."""
    cfg = reduced_config("jamba-v0.1-52b")
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    work = [(np.arange(4, dtype=np.int32) + 1, 2) for _ in range(2)]

    def elems(max_seq, **kw):
        eng = DecodeEngine(cfg, params, batch_slots=2, max_seq=max_seq,
                           steps_per_sync=2, **kw)
        for p, m in work:
            eng.submit(Request(prompt=p, max_new_tokens=m))
        eng.run_until_drained()
        return eng.stats["admit_cache_elems"]

    rec = {"dense_64": elems(64), "dense_128": elems(128),
           "paged_64": elems(64, kv_layout="paged", page_size=8),
           "paged_128": elems(128, kv_layout="paged", page_size=8)}
    assert rec["dense_128"] > rec["dense_64"], \
        "dense admission cost should scale with max_seq"
    assert rec["paged_128"] == rec["paged_64"], \
        "paged admission cost must not scale with max_seq"
    print(f"[memory] admission elems: dense {rec['dense_64']}->"
          f"{rec['dense_128']} vs paged {rec['paged_64']}->"
          f"{rec['paged_128']} (64->128 max_seq)")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + hard floors, for CI")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_serve.json"))
    args = ap.parse_args(argv)

    cfg, params = _cfg_params()
    out: dict = {"arch": ARCH, "layers": 2, "slots": SLOTS,
                 "max_seq": MAX_SEQ, "smoke": bool(args.smoke),
                 "backend": jax.default_backend()}
    bench_decode_kernel(out, smoke=args.smoke)
    bench_throughput(out, cfg, params, smoke=args.smoke)
    bench_prefill(out, cfg, params, smoke=args.smoke)
    bench_poisson(out, cfg, params, smoke=args.smoke)
    bench_memory(out, cfg, params, smoke=args.smoke)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[serve_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
