"""Cost-aware scheduling benchmark: scaling/budget policies + CostMeter.

Quantifies the paper's "budget-effective" claim with the pluggable policy
layer on a cost model with per-instance minimum billing (clouds bill a
minimum commitment per started instance, so over-provisioning is real
money, not just a BYE round trip):

  * fixed-fleet vs demand scaling on a ramp-bound sweep — the fixed
    policy creates instances as long as any task is assignable and boots
    a fleet the workload can't fill; demand scaling stops once committed
    worker capacity covers the remaining work,
  * a user-set budget cap on the fixed policy — scaling halts when the
    projected spend threatens the cap; the run still solves everything,
    just with a smaller fleet.

Results land in BENCH_sched.json at the repo root.

Usage:
    PYTHONPATH=src python benchmarks/sched_cost_bench.py [--smoke] [--out F]

``--smoke`` asserts the demand-scaling saving floor and the budget cap,
for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.experiment import Experiment               # noqa: E402
from repro.core.policy import CostMeter                    # noqa: E402
from repro.core.server import ServerConfig                 # noqa: E402
from repro.core.sim import SimParams, SimTask              # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_TASKS = 24
TASK_S = 30.0
MAX_CLIENTS = 16
WORKERS = 4
MIN_BILLING_S = 60.0
BUDGET_CAP = 400.0
BUDGET_RESERVE_S = 90.0


def _workload():
    return [SimTask((i, 0), ("n", "id"), (i,), TASK_S, None, (i,))
            for i in range(1, N_TASKS + 1)]


def _run(scale: str, budget_cap: float | None = None,
         shards: int = 1) -> dict:
    cfg = ServerConfig(max_clients=MAX_CLIENTS, use_backup=False,
                       workers_hint=WORKERS, scale_policy=scale,
                       budget_cap=budget_cap,
                       budget_reserve_s=BUDGET_RESERVE_S)
    h = Experiment(_workload(), engine="sim",
                   sim=SimParams(client_workers=WORKERS, seed=0,
                                 min_billing_s=MIN_BILLING_S),
                   shards=shards, config=cfg).run()
    cl = h.cluster
    engines = cl.engines if shards > 1 else [cl.engine]
    t0 = time.perf_counter()
    table = h.results(until=3600)
    # let the BYE round trips drain so every client instance is closed
    # (each shard engine keeps its own primary alive)
    steps = 0
    while sum(len(e.list_instances()) for e in engines) > len(engines) \
            and steps < 3000:
        cl.step()
        steps += 1
    wall = time.perf_counter() - t0
    now = cl.clock.now()
    # one CostMeter per shard engine (shard engines each bill their own
    # "primary"), aggregated by summing — the run-level summary on the
    # merged table (table.cost) is the same aggregation done server-side
    # via merge_cost_summaries
    meters = []
    for e in engines:
        meter = CostMeter()
        meter.sync(e.billing_records())
        meters.append(meter)
    assert table.cost is not None \
        and table.cost["total"] > 0, "cost column not populated"
    assert table.row_costs is not None \
        and any(c is not None for c in table.row_costs)
    return {
        "scale_policy": scale,
        "budget_cap": budget_cap,
        "shards": shards,
        "clients_created": sum(1 for e in engines
                               for _, k in e._kinds.items()
                               if k == "client"),
        "solved": sum(1 for _, r, _ in table.rows if r is not None),
        "tasks": len(table.rows),
        "makespan_s": round(now, 1),
        "total_cost": round(sum(m.accrued(now) for m in meters), 1),
        "client_cost": round(sum(m.by_kind(now).get("client", 0.0)
                                 for m in meters), 1),
        "cost_at_done": table.cost["total"],
        "wall_s": round(wall, 4),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="assert saving floor + budget cap (CI)")
    ap.add_argument("--shards", type=int, default=2,
                    help="shard count for the sharded cost-accounting run "
                         "(CostMeter aggregated across shards into one "
                         "ResultsTable summary)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_sched.json"))
    args = ap.parse_args(argv)

    fixed = _run("fixed")
    demand = _run("demand")
    capped = _run("fixed", budget_cap=BUDGET_CAP)
    sharded = _run("demand", shards=args.shards)
    saving = 1.0 - demand["client_cost"] / max(fixed["client_cost"], 1e-9)

    for r in (fixed, demand, capped, sharded):
        cap = f" cap={r['budget_cap']}" if r["budget_cap"] else ""
        shard_note = f" x{r['shards']}sh" if r["shards"] > 1 else ""
        print(f"{r['scale_policy']:6s}{cap:9s}{shard_note:6s}: "
              f"{r['clients_created']:2d} clients, "
              f"cost {r['total_cost']:7.1f}, "
              f"makespan {r['makespan_s']:6.1f}s, "
              f"solved {r['solved']}/{r['tasks']}")
    print(f"demand-scaling client-cost saving: {100 * saving:.0f}%")

    out = {
        "bench": "sched_cost",
        "scenario": {
            "n_tasks": N_TASKS, "task_s": TASK_S,
            "max_clients": MAX_CLIENTS, "workers": WORKERS,
            "min_billing_s": MIN_BILLING_S,
        },
        "fixed": fixed,
        "demand": demand,
        "budget_capped": capped,
        "sharded_demand": sharded,
        "demand_saving_pct": round(100 * saving, 1),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")

    if args.smoke:
        # regression tripwires (virtual clock -> deterministic, not noisy)
        assert fixed["solved"] == demand["solved"] == capped["solved"] \
            == N_TASKS, out
        assert out["demand_saving_pct"] >= 25.0, out
        assert capped["total_cost"] <= BUDGET_CAP, out
        assert capped["clients_created"] < fixed["clients_created"], out
        # sharded run: every task solved and the merged table carries an
        # across-shards cost summary consistent with the engine meters
        assert sharded["solved"] == N_TASKS, out
        assert sharded["cost_at_done"] > 0, out
        assert sharded["total_cost"] > 0, out
    return out


if __name__ == "__main__":
    main()
