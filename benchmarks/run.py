# One function per paper claim / system layer. Prints
# ``name,us_per_call,derived`` CSV (see each module for what is measured).
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import expocloud_bench, kernel_bench, roofline_bench, \
        train_bench

    rows = []
    for mod in (expocloud_bench, kernel_bench, train_bench, roofline_bench):
        try:
            rows.extend(mod.run_all())
        except Exception as e:  # noqa: BLE001 — report and continue
            rows.append((f"{mod.__name__}_FAILED", 0.0, repr(e)[:80]))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
