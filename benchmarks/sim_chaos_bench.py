"""Chaos benchmark: fault-injection scenarios for the discrete-event sim.

Runs the same fault-tolerant parameter-sweep workload under four network
conditions and measures completeness (solved tasks — the paper's "no
results are lost" claim under *partial* failures, not just node kills)
and the cost/makespan overhead each failure mode induces:

  * ``clean``     — no partitions (baseline),
  * ``oneway``    — one-way primary->client link loss for a window
    (grants die silently; the client keeps heartbeating: regrant +
    request-retry must recover every stranded assignment),
  * ``pb_freeze`` — the primary<->backup link partitions across the
    freeze/backup-creation window and heals later (the backup must
    neither take over (grace) nor drift (gap-detected resync)),
  * ``flapping``  — every 2 s each client link goes dark for 1 s with
    probability 0.2, random direction (seeded).

Also validates the trace record/replay mode end to end: the clean run is
recorded (with latency jitter enabled) and replayed via
``SimParams(trace=...)`` with different jitter/seed parameters — the
replay must reproduce the recorded run's results table row-for-row.

Results land in BENCH_chaos.json at the repo root.

Usage:
    PYTHONPATH=src python benchmarks/sim_chaos_bench.py [--smoke] [--out F]

``--smoke`` asserts zero lost tasks in every scenario + replay identity
(CI tripwire).
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.experiment import Experiment        # noqa: E402
from repro.core.server import ServerConfig          # noqa: E402
from repro.core.sim import SimCluster, SimParams, SimTask   # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _workload(n: int, dur_lo: float = 1.5, dur_hi: float = 4.0):
    return [SimTask((i, 0), ("n", "id"), (i,),
                    dur_lo + (dur_hi - dur_lo) * ((i * 7) % n) / n,
                    None, (i,))
            for i in range(1, n + 1)]


def _cluster(n_tasks: int, n_clients: int, params: SimParams) -> SimCluster:
    # the facade resolves the sim engine; chaos is scripted directly on
    # the handle's cluster below (the advanced-scripting surface)
    return Experiment(
        _workload(n_tasks),
        engine="sim", engine_cfg={"params": params},
        config=ServerConfig(max_clients=n_clients, use_backup=True,
                            health_update_limit=4.0, partition_grace_s=8.0),
    ).run().cluster


def _script_scenario(cl: SimCluster, scenario: str):
    if scenario == "clean":
        return
    if scenario == "oneway":
        # grants to the first client die for 9 s mid-run ("client-1":
        # with use_backup the backup instance takes name counter 0)
        cl.partition("primary", "client-1", direction="a2b",
                     at=4.0, until=13.0)
    elif scenario == "pb_freeze":
        # the pb link is dark across the freeze/backup-creation window
        # (backup creation starts immediately; creation_delay ~2 s)
        cl.partition("primary", "backup", at=1.0, until=9.0)
    elif scenario == "flapping":
        rng = random.Random(1234)

        def flap(c):
            for node in c.clients():
                if not c.engine.alive.get(node.name, False):
                    continue
                if rng.random() < 0.2:
                    direction = rng.choice(["a2b", "b2a", "both"])
                    c.engine.partition("primary", node.name, direction,
                                       until=c.clock.now() + 1.0)
            if c.clock.now() < 30.0:
                c.at(c.clock.now() + 2.0, flap)

        cl.at(2.0, flap)
    else:
        raise ValueError(scenario)


def run_scenario(scenario: str, n_tasks: int, n_clients: int) -> dict:
    params = SimParams(client_workers=2, seed=0)
    cl = _cluster(n_tasks, n_clients, params)
    _script_scenario(cl, scenario)
    t0 = time.perf_counter()
    srv = cl.run(until=1e6, max_steps=20_000_000)
    wall = time.perf_counter() - t0
    solved = sum(1 for _, r, _ in srv.final_results.rows if r is not None)
    return {
        "scenario": scenario,
        "tasks": len(srv.final_results.rows),
        "solved": solved,
        "results_exactly_once": len(srv.results) == solved,
        "sim_makespan_s": round(cl.clock.now(), 3),
        "wall_s": round(wall, 4),
        "events": cl.loop.processed,
        "cost": round(cl.engine.total_cost(), 1),
        "acting_primary": cl.acting_primary().name,
        "rows": srv.final_results.rows,
    }


def run_trace_replay(n_tasks: int, n_clients: int) -> dict:
    """Record a jittery run (with a spot wave), replay it via
    SimParams(trace=...), assert row-identical tables."""
    rec = _cluster(n_tasks, n_clients,
                   SimParams(client_workers=2, seed=3, latency_jitter=0.04,
                             record_trace=True))
    rec.spot_wave(6.0, 0.3)
    srv = rec.run(until=1e6, max_steps=20_000_000)
    trace = rec.trace()
    rep = _cluster(n_tasks, n_clients,
                   SimParams(client_workers=2, seed=999, latency_jitter=0.0,
                             trace=trace))
    srv2 = rep.run(until=1e6, max_steps=20_000_000)
    identical = srv2.final_results.rows == srv.final_results.rows
    return {
        "recorded_makespan_s": round(rec.clock.now(), 3),
        "replayed_makespan_s": round(rep.clock.now(), 3),
        "recorded_message_delays": sum(
            len(v) for v in trace.message_delays.values()),
        "recorded_preemptions": len(trace.preemptions),
        "rows_identical": identical,
    }


SCENARIOS = ("clean", "oneway", "pb_freeze", "flapping")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="assert zero lost tasks + replay identity (CI)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_chaos.json"))
    args = ap.parse_args(argv)

    n_tasks, n_clients = (36, 3) if args.smoke else (96, 6)

    runs = []
    clean = None
    for scenario in SCENARIOS:
        r = run_scenario(scenario, n_tasks, n_clients)
        rows = r.pop("rows")
        if scenario == "clean":
            clean, clean_rows = r, rows
            r["makespan_overhead"] = r["cost_overhead"] = 1.0
        else:
            r["makespan_overhead"] = round(
                r["sim_makespan_s"] / clean["sim_makespan_s"], 3)
            r["cost_overhead"] = round(r["cost"] / clean["cost"], 3)
            # chaos may reorder completions but never lose or invent rows
            assert sorted(map(str, rows)) == sorted(map(str, clean_rows)), \
                f"{scenario}: results differ from the clean run"
        runs.append(r)
        print(f"{scenario:9s}: solved {r['solved']}/{r['tasks']}  "
              f"makespan={r['sim_makespan_s']:7.1f}s "
              f"(x{r['makespan_overhead']:.2f})  "
              f"cost={r['cost']:8.1f} (x{r['cost_overhead']:.2f})  "
              f"primary={r['acting_primary']}")

    replay = run_trace_replay(n_tasks, n_clients)
    print(f"trace replay: recorded {replay['recorded_makespan_s']}s "
          f"({replay['recorded_message_delays']} message delays, "
          f"{replay['recorded_preemptions']} preemptions) -> "
          f"identical rows: {replay['rows_identical']}")

    out = {"bench": "sim_chaos", "n_tasks": n_tasks,
           "n_clients": n_clients, "scenarios": runs,
           "trace_replay": replay}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")

    if args.smoke:
        for r in runs:
            assert r["solved"] == r["tasks"], \
                f"{r['scenario']}: lost {r['tasks'] - r['solved']} tasks"
            assert r["results_exactly_once"], r["scenario"]
        assert replay["rows_identical"], "trace replay diverged"
    return out


if __name__ == "__main__":
    main()
