"""Substrate benchmarks: training-step wall time and serving throughput on
reduced configs (CPU) — regression tracking for the framework layers."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.data.synthetic import batch_at, data_config_for
from repro.models import lm
from repro.models.params import init_params
from repro.train.optimizer import get_optimizer
from repro.train.schedule import constant
from repro.train.train_step import make_train_step


def bench_train_step(arch="smollm-360m"):
    cfg = reduced_config(arch)
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    opt = get_optimizer("adamw")
    state = opt.init(params)
    dc = data_config_for(cfg, seq_len=64, batch_size=4)
    # no donation here: freshly-initialised m/v zeros may alias the same
    # buffer, and XLA rejects donating one buffer twice
    step_fn = jax.jit(make_train_step(cfg, opt, constant(1e-3)))
    batch = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}
    params, state, m = step_fn(params, state, batch, jnp.int32(0))
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    iters = 5
    for i in range(iters):
        batch = {k: jnp.asarray(v) for k, v in batch_at(dc, i + 1).items()}
        params, state, m = step_fn(params, state, batch, jnp.int32(i))
    jax.block_until_ready(m["loss"])
    us = (time.perf_counter() - t0) / iters * 1e6
    toks = dc.seq_len * dc.batch_size
    return [(f"train_step_{arch}", us, f"{toks/us*1e6:.0f} tok/s")]


def bench_decode_throughput(arch="mamba2-130m"):
    from repro.serve.engine import DecodeEngine, Request

    cfg = reduced_config(arch)
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, batch_slots=4, max_seq=96)
    for i in range(4):
        eng.submit(Request(prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=16))
    t0 = time.perf_counter()
    steps = eng.run_until_drained()
    dt = time.perf_counter() - t0
    us = dt / max(steps, 1) * 1e6
    return [(f"decode_step_{arch}", us,
             f"{4*16/dt:.0f} tok/s over 4 slots")]


def run_all():
    rows = []
    rows += bench_train_step("smollm-360m")
    rows += bench_train_step("mamba2-130m")
    rows += bench_decode_throughput("mamba2-130m")
    rows += bench_decode_throughput("smollm-360m")
    return rows
