"""Serving example: continuous-batching decode engine over batched requests.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m \
        --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import lm
from repro.models.params import init_params
from repro.serve.engine import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, batch_slots=args.slots, max_seq=128)

    reqs = []
    for i in range(args.requests):
        if cfg.num_codebooks:
            prompt = np.ones((3 + i % 3, cfg.num_codebooks), np.int32) * (i + 1)
        else:
            prompt = (np.arange(3 + i % 3, dtype=np.int32) + 1 + i) \
                % cfg.vocab_size
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new,
                            temperature=args.temperature))
        eng.submit(reqs[-1])

    t0 = time.time()
    steps = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests in {steps} decode steps, "
          f"{dt:.1f}s -> {total_tokens/dt:.1f} tok/s "
          f"({args.slots} slots, continuous batching)")
    for i, r in enumerate(reqs[:4]):
        toks = [int(np.asarray(t).flat[0]) for t in r.output]
        print(f"  req{i}: {toks}")


if __name__ == "__main__":
    main()
