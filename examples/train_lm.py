"""End-to-end training driver: train an LM for a few hundred steps with
checkpoint/restart, optionally through ExpoCloud (so a worker crash or
preemption resumes from the latest checkpoint when the task is re-assigned).

CPU-sized default (reduced config; ~1M params):
    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
        --steps 300 --preset reduced

Full-config run (e.g. mamba2-130m, the ~130M-param assigned arch — sized
for a real accelerator, will be slow on CPU):
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m \
        --steps 300 --preset full --seq 256 --batch 4

Through ExpoCloud with a simulated mid-run failure:
    PYTHONPATH=src python examples/train_lm.py --expocloud --fail-once
"""
from __future__ import annotations

import argparse
import os

from repro.configs import get_config, reduced_config
from repro.core.task import AbstractTask
from repro.data.synthetic import data_config_for
from repro.train.loop import TrainJob, run_training


class TrainLMTask(AbstractTask):
    """Training as an ExpoCloud task: re-assignment after a failure resumes
    from the checkpoint directory (at-least-once -> exactly-resumed)."""

    def __init__(self, arch, preset, steps, seq, batch, ckpt_dir,
                 fail_once=False):
        self.arch, self.preset = arch, preset
        self.steps, self.seq, self.batch = steps, seq, batch
        self.ckpt_dir = ckpt_dir
        self.fail_once = fail_once
        self.sim_duration = 1.0

    def parameter_titles(self):
        return ("arch", "preset", "steps", "id")

    def parameters(self):
        return (self.arch, self.preset, self.steps, 0)

    def hardness_parameters(self):
        return (self.steps * self.seq * self.batch,)

    def result_titles(self):
        return ("final_step", "first_loss", "last_loss")

    def run(self):
        cfg = (reduced_config(self.arch) if self.preset == "reduced"
               else get_config(self.arch))
        dc = data_config_for(cfg, seq_len=self.seq, batch_size=self.batch)
        fail_marker = os.path.join(self.ckpt_dir, ".failed_once")
        fail_after = None
        if self.fail_once and not os.path.exists(fail_marker):
            open(fail_marker, "w").close()
            fail_after = self.steps // 3
        job = TrainJob(total_steps=self.steps, ckpt_every=25,
                       ckpt_dir=self.ckpt_dir, log_every=25, warmup=10,
                       fail_after_step=fail_after)
        hist, final, _ = run_training(cfg, dc, job)
        return (final, round(hist[0]["loss"], 4), round(hist[-1]["loss"], 4))

    def timeout(self):
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--preset", choices=["reduced", "full"],
                    default="reduced")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--expocloud", action="store_true")
    ap.add_argument("--fail-once", action="store_true",
                    help="inject one failure to demo checkpoint restart")
    args = ap.parse_args()

    task = TrainLMTask(args.arch, args.preset, args.steps, args.seq,
                       args.batch, args.ckpt_dir, args.fail_once)
    if not args.expocloud:
        if args.fail_once:
            try:
                task.run()
            except RuntimeError as e:
                print(f"[train_lm] injected failure: {e}; restarting ...")
        print("[train_lm] result:", task.run())
        return

    from repro.core.engine import LocalEngine
    from repro.core.server import Server, ServerConfig

    engine = LocalEngine(n_workers_per_client=1)
    srv = Server([task], engine,
                 ServerConfig(max_clients=1, use_backup=False,
                              health_update_limit=600.0))
    table = srv.run(poll_sleep=0.2)
    engine.shutdown()
    print(table.to_csv())


if __name__ == "__main__":
    main()
