"""Quickstart: the three layers of the framework.

 1. ExpoCloud (the paper): a parameter sweep through the unified
    Experiment facade — declare a ParamSpace, decorate a function with
    @task, run it on the simulated cloud (or engine="local"/"gce"/"tpu":
    the same call drives real instances).
 2. Substrate: train a reduced LM for a few steps with checkpointing.
 3. Dry-run: lower+compile one cell on a small host-device mesh and print
    its roofline terms (full 512-device runs: repro.launch.sweep_dryrun).

    PYTHONPATH=src python examples/quickstart.py [--section sweep|train|dryrun]
"""
import argparse
import os
import subprocess
import sys
import tempfile

from repro.core import (Experiment, InstanceType, ParamSpace, SpotWave,
                        axis, task)


@task(result_titles=("n_squared",), timeout=3.0,
      sim_duration=lambda n, **_: 0.4 * n)
def square(n, id):
    return (n * n,)


# ---------------------------------------------------------------- 1. sweep
def sweep():
    space = ParamSpace.grid(n=axis(range(1, 11), hardness="asc"), id=[0])
    exp = Experiment(
        space.bind(square), engine="sim", max_clients=2,
        sim=dict(client_workers=1, latency_jitter=0.002, seed=0,
                 instance_types={"client": InstanceType(
                     creation_delay=1.0, cost_per_instance_second=2.0)}),
        chaos=[SpotWave(at=5.0, fraction=0.5)])  # spot wave takes half the
    with exp.run() as run:                       # fleet at t=5s
        table = run.results(until=600)

    print("[1] ExpoCloud sweep:")
    print("    solved:",
          [p[0] for p, r, s in table.rows if r is not None],
          "| pruned by domino:",
          [p[0] for p, r, s in table.rows if s == "pruned"])
    cost = table.cost   # CostMeter summary, engine -> results
    cluster = run.cluster
    print(f"    makespan {cluster.clock.now():.1f}s simulated in "
          f"{cluster.loop.processed} events, "
          f"cost {cost['total']:.0f} (rate-weighted instance-seconds, "
          f"by kind: {cost['by_kind']})")


# ---------------------------------------------------------------- 2. train
def train():
    from repro.configs import reduced_config
    from repro.data.synthetic import data_config_for
    from repro.train.loop import TrainJob, run_training

    cfg = reduced_config("smollm-360m")
    dc = data_config_for(cfg, seq_len=64, batch_size=4)
    with tempfile.TemporaryDirectory() as td:
        hist, _, _ = run_training(
            cfg, dc, TrainJob(total_steps=20, ckpt_every=10, ckpt_dir=td,
                              log_every=10, warmup=5))
    print(f"[2] trained reduced smollm: loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f}")


# ---------------------------------------------------------------- 3. dryrun
def dryrun():
    print("[3] dry-run one cell on an 8-device host mesh:")
    env = dict(os.environ, PYTHONPATH="src", REPRO_DRYRUN_DEVICES="8")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-130m", "--shape", "train_4k", "--mesh-shape", "2", "4",
         "--mesh-axes", "data", "model"],
        env=env, check=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=["all", "sweep", "train", "dryrun"],
                    default="all")
    args = ap.parse_args()
    if args.section in ("all", "sweep"):
        sweep()
    if args.section in ("all", "train"):
        train()
    if args.section in ("all", "dryrun"):
        dryrun()
