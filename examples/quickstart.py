"""Quickstart: the three layers of the framework in ~60 lines.

 1. ExpoCloud (the paper): run a parameter sweep on the simulated cloud.
 2. Substrate: train a reduced LM for a few steps with checkpointing.
 3. Dry-run: lower+compile one cell on a small host-device mesh and print
    its roofline terms (full 512-device runs: repro.launch.sweep_dryrun).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import subprocess
import sys
import tempfile

# ---------------------------------------------------------------- 1. sweep
from repro.core.server import ServerConfig
from repro.core.sim import InstanceType, SimCluster, SimParams, SimTask

tasks = [SimTask((n, 0), ("n", "id"), (n,), sim_duration=0.4 * n,
                 deadline=3.0, result=(n * n,))
         for n in range(1, 11)]
# The simulator is a discrete-event engine: the clock jumps between
# message deliveries / worker completions, so scenarios with latency
# jitter, heterogeneous instance types and spot-preemption waves replay
# deterministically in milliseconds of wall time.
params = SimParams(
    client_workers=1, latency_jitter=0.002, seed=0,
    instance_types={"client": InstanceType(creation_delay=1.0,
                                           cost_per_instance_second=2.0)})
cluster = SimCluster(tasks, ServerConfig(max_clients=2, use_backup=False),
                     params)
cluster.spot_wave(5.0, 0.5)    # a spot wave takes half the fleet at t=5s
server = cluster.run(until=600)
print("[1] ExpoCloud sweep:")
print("    solved:",
      [p[0] for p, r, s in server.final_results.rows if r is not None],
      "| pruned by domino:",
      [p[0] for p, r, s in server.final_results.rows if s == "pruned"])
cost = server.final_results.cost   # CostMeter summary, engine -> results
print(f"    makespan {cluster.clock.now():.1f}s simulated in "
      f"{cluster.loop.processed} events, "
      f"cost {cost['total']:.0f} (rate-weighted instance-seconds, "
      f"by kind: {cost['by_kind']})")

# ---------------------------------------------------------------- 2. train
from repro.configs import reduced_config
from repro.data.synthetic import data_config_for
from repro.train.loop import TrainJob, run_training

cfg = reduced_config("smollm-360m")
dc = data_config_for(cfg, seq_len=64, batch_size=4)
with tempfile.TemporaryDirectory() as td:
    hist, _, _ = run_training(
        cfg, dc, TrainJob(total_steps=20, ckpt_every=10, ckpt_dir=td,
                          log_every=10, warmup=5))
print(f"[2] trained reduced smollm: loss {hist[0]['loss']:.3f} -> "
      f"{hist[-1]['loss']:.3f}")

# ---------------------------------------------------------------- 3. dryrun
print("[3] dry-run one cell on an 8-device host mesh:")
env = dict(os.environ, PYTHONPATH="src", REPRO_DRYRUN_DEVICES="8")
subprocess.run(
    [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
     "--shape", "train_4k", "--mesh-shape", "2", "4",
     "--mesh-axes", "data", "model"],
    env=env, check=True)
