"""The paper's worked example: exploring the parameter space of branch-and-
bound search for the agent assignment problem.

Problem (paper §"The example parameter exploration"): n agents, m tasks done
sequentially, t_ij = time agent i needs for task j; assign distinct agents
to tasks minimising total time.  Three algorithm variants:

  * brute  — brute-force DFS over assignments (NO_CUTOFFS),
  * bnb    — B&B cutoff on the incumbent,
  * bnb+h  — B&B + admissible lower bound (best remaining agent per
             remaining task, reuse allowed).

The exploration is declared with the unified API: a ``ParamSpace`` whose
axes carry their hardness direction (the paper's observation that each
coordinate is monotone in runtime) and a ``@task`` function — one cell =
one variant solving one generated instance for one (n_tasks, n_agents)
setting.  ``Experiment`` drives it on any engine:

Run locally (real processes, the paper's local engine):
    PYTHONPATH=src python examples/agent_assignment.py --engine local
Deterministic virtual-cloud simulation (fast, used by benchmarks):
    PYTHONPATH=src python examples/agent_assignment.py --engine sim
"""
from __future__ import annotations

import argparse
import enum
import time

import numpy as np

from repro.core import Experiment, ParamSpace, axis, task


class Option(enum.Enum):
    NO_CUTOFFS = "no_cutoffs"
    HEURISTIC = "heuristic"


ALG_OPTIONS = {
    "brute": frozenset({Option.NO_CUTOFFS}),
    "bnb": frozenset(),
    "bnb+h": frozenset({Option.HEURISTIC}),
}
# Brute force (2) > classic B&B (1) > B&B+heuristic (0).
ALG_HARDNESS = {"brute": 2, "bnb": 1, "bnb+h": 0}


def generate_instance(n_agents: int, n_tasks: int, instance_id: int,
                      seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng([seed, n_agents, n_tasks, instance_id])
    return rng.integers(1, 100, size=(n_agents, n_tasks)).astype(np.int64)


def bnb_search(t: np.ndarray, options: frozenset):
    """Returns (optimal_time, nodes_expanded)."""
    n_agents, n_tasks = t.shape
    use_cutoff = Option.NO_CUTOFFS not in options
    use_heur = Option.HEURISTIC in options
    best = [np.sum(np.max(t, axis=0)) + 1]  # upper bound
    nodes = [0]
    used = np.zeros(n_agents, bool)
    # admissible heuristic: best unused agent per remaining task (reusable)
    def heuristic(j):
        if not use_heur:
            return 0
        rem = t[~used][:, j:]
        return int(np.sum(np.min(rem, axis=0))) if rem.size else 0

    def rec(j, acc):
        nodes[0] += 1
        if j == n_tasks:
            best[0] = min(best[0], acc)
            return
        if use_cutoff and acc + heuristic(j) >= best[0]:
            return
        order = np.argsort(t[:, j])
        for i in order:
            if used[i]:
                continue
            used[i] = True
            rec(j + 1, acc + int(t[i, j]))
            used[i] = False

    rec(0, 0)
    return int(best[0]), nodes[0]


def _sim_duration(alg, n_tasks, n_agents, **_):
    """Virtual duration for the simulator: exponential in problem size,
    scaled by the variant (mirrors real B&B behaviour)."""
    factor = {2: 1.0, 1: 0.25, 0: 0.08}[ALG_HARDNESS[alg]]
    return factor * 1.4 ** (n_tasks + 0.5 * n_agents) * 1e-2


@task(result_titles=("optimal_time", "nodes", "seconds"),
      sim_duration=_sim_duration)
def solve(alg, n_tasks, n_agents, id):
    """The researcher-written task function (replaces the paper's 7-method
    Task subclass — titles, hardness and grouping come from the space)."""
    t = generate_instance(n_agents, n_tasks, id)
    t0 = time.time()
    opt, nodes = bnb_search(t, ALG_OPTIONS[alg])
    return (opt, nodes, round(time.time() - t0, 4))


def build_space(max_n_tasks: int = 8,
                n_instances_per_setting: int = 3) -> ParamSpace:
    """The paper's nested loops, declared: hardness = (variant, n_tasks,
    n_agents), each axis monotone in runtime; n_agents is a dependent
    axis (>= n_tasks)."""
    return ParamSpace.grid(
        alg=axis(["brute", "bnb", "bnb+h"],
                 hardness=lambda v: ALG_HARDNESS[v]),
        n_tasks=axis(range(2, max_n_tasks + 1), hardness="asc"),
        n_agents=axis(lambda c: range(c["n_tasks"], max_n_tasks + 1),
                      hardness="asc"),
        id=range(n_instances_per_setting),
    ).bind(solve)


def build_tasks(max_n_tasks: int = 8, n_instances_per_setting: int = 3,
                deadline: float = 5.0):
    """Materialized task list (kept for tests/benchmarks)."""
    return build_space(max_n_tasks, n_instances_per_setting).tasks(
        timeout=deadline)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=["local", "sim"], default="sim")
    ap.add_argument("--max-n", type=int, default=8)
    ap.add_argument("--instances", type=int, default=3)
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--min-group-size", type=int, default=2)
    ap.add_argument("--out", default=None)
    ap.add_argument("--scale", choices=["fixed", "demand"], default="fixed",
                    help="fleet-scaling policy (see repro.core.policy)")
    ap.add_argument("--budget-cap", type=float, default=None,
                    help="stop scaling when this spend cap is threatened")
    args = ap.parse_args()

    space = build_space(args.max_n, args.instances)
    print(f"{len(space)} tasks")
    engine_cfg = {"client_workers": 4} if args.engine == "sim" \
        else {"n_workers_per_client": 2}
    exp = Experiment(
        space.tasks(timeout=args.deadline),
        engine=args.engine, engine_cfg=engine_cfg,
        scale=args.scale, budget_cap=args.budget_cap,
        backup=(args.engine == "sim"),   # paper: no backup locally
        max_clients=3, out_dir=args.out,
        min_group_size=args.min_group_size, workers_hint=4)
    with exp.run() as run:
        table = run.results(until=3600, poll_sleep=0.05)
        if args.engine == "sim":
            print(f"simulated makespan {run.cluster.clock.now():.1f}s, "
                  f"cost {table.cost['total']:.0f} instance-seconds "
                  f"(by kind: {table.cost['by_kind']})")
    solved = len(table.solved_rows())
    print(f"solved {solved}/{len(table.rows)} retained rows "
          f"(dropped groups: {len(table.dropped_groups)})")
    print("\n".join(table.to_csv().splitlines()[:12]))


if __name__ == "__main__":
    main()
