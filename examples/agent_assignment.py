"""The paper's worked example: exploring the parameter space of branch-and-
bound search for the agent assignment problem.

Problem (paper §"The example parameter exploration"): n agents, m tasks done
sequentially, t_ij = time agent i needs for task j; assign distinct agents
to tasks minimising total time.  Three algorithm variants:

  * NO_CUTOFFS  — brute-force DFS over assignments,
  * (classic)   — B&B cutoff on the incumbent,
  * HEURISTIC   — B&B + admissible lower bound (best remaining agent per
                  remaining task, reuse allowed).

Each ExpoCloud task = one variant solving one generated instance for one
(n_tasks, n_agents) setting.  Hardness = (variant, n_tasks, n_agents) —
exactly the paper's observation that each coordinate is monotone in runtime.

Run locally (real processes, the paper's local engine):
    PYTHONPATH=src python examples/agent_assignment.py --engine local
Deterministic virtual-cloud simulation (fast, used by benchmarks):
    PYTHONPATH=src python examples/agent_assignment.py --engine sim
"""
from __future__ import annotations

import argparse
import enum
import time

import numpy as np

from repro.core.task import AbstractTask, filter_out


class Option(enum.Enum):
    NO_CUTOFFS = "no_cutoffs"
    HEURISTIC = "heuristic"


def options2hardness(options: frozenset) -> int:
    """Brute force (2) > classic B&B (1) > B&B+heuristic (0)."""
    if Option.NO_CUTOFFS in options:
        return 2
    if Option.HEURISTIC in options:
        return 0
    return 1


def options2name(options: frozenset) -> str:
    if Option.NO_CUTOFFS in options:
        return "brute"
    if Option.HEURISTIC in options:
        return "bnb+h"
    return "bnb"


def generate_instance(n_agents: int, n_tasks: int, instance_id: int,
                      seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng([seed, n_agents, n_tasks, instance_id])
    return rng.integers(1, 100, size=(n_agents, n_tasks)).astype(np.int64)


def bnb_search(t: np.ndarray, options: frozenset):
    """Returns (optimal_time, nodes_expanded)."""
    n_agents, n_tasks = t.shape
    use_cutoff = Option.NO_CUTOFFS not in options
    use_heur = Option.HEURISTIC in options
    best = [np.sum(np.max(t, axis=0)) + 1]  # upper bound
    nodes = [0]
    used = np.zeros(n_agents, bool)
    # admissible heuristic: best unused agent per remaining task (reusable)
    def heuristic(j):
        if not use_heur:
            return 0
        rem = t[~used][:, j:]
        return int(np.sum(np.min(rem, axis=0))) if rem.size else 0

    def rec(j, acc):
        nodes[0] += 1
        if j == n_tasks:
            best[0] = min(best[0], acc)
            return
        if use_cutoff and acc + heuristic(j) >= best[0]:
            return
        order = np.argsort(t[:, j])
        for i in order:
            if used[i]:
                continue
            used[i] = True
            rec(j + 1, acc + int(t[i, j]))
            used[i] = False

    rec(0, 0)
    return int(best[0]), nodes[0]


class AgentAssignmentTask(AbstractTask):
    """The researcher-written Task class from the paper."""

    def __init__(self, options: frozenset, n_tasks: int, n_agents: int,
                 instance_id: int, deadline: float | None = 10.0,
                 seed: int = 0):
        self.options = frozenset(options)
        self.n_tasks = n_tasks
        self.n_agents = n_agents
        self.instance_id = instance_id
        self.deadline = deadline
        self.seed = seed
        # virtual duration for the simulator: exponential in problem size,
        # scaled by the variant (mirrors real B&B behaviour)
        factor = {2: 1.0, 1: 0.25, 0: 0.08}[options2hardness(self.options)]
        self.sim_duration = factor * 1.4 ** (n_tasks + 0.5 * n_agents) * 1e-2

    def parameter_titles(self):
        return ("alg", "n_tasks", "n_agents", "id")

    def parameters(self):
        return (options2name(self.options), self.n_tasks, self.n_agents,
                self.instance_id)

    def hardness_parameters(self):
        return (options2hardness(self.options), self.n_tasks, self.n_agents)

    def result_titles(self):
        return ("optimal_time", "nodes", "seconds")

    def run(self):
        t = generate_instance(self.n_agents, self.n_tasks, self.instance_id,
                              self.seed)
        t0 = time.time()
        opt, nodes = bnb_search(t, self.options)
        return (opt, nodes, round(time.time() - t0, 4))

    def timeout(self):
        return self.deadline

    def group_parameter_titles(self):
        return filter_out(self.parameter_titles(), ("id",))


def build_tasks(max_n_tasks: int = 8, n_instances_per_setting: int = 3,
                deadline: float = 5.0):
    """The paper's nested loops (scaled down for a laptop-sized demo)."""
    tasks = []
    for options in [frozenset({Option.NO_CUTOFFS}), frozenset(),
                    frozenset({Option.HEURISTIC})]:
        for n_tasks in range(2, max_n_tasks + 1):
            for n_agents in range(n_tasks, max_n_tasks + 1):
                for i in range(n_instances_per_setting):
                    tasks.append(AgentAssignmentTask(
                        options, n_tasks, n_agents, i, deadline))
    return tasks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=["local", "sim"], default="sim")
    ap.add_argument("--max-n", type=int, default=8)
    ap.add_argument("--instances", type=int, default=3)
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--min-group-size", type=int, default=2)
    ap.add_argument("--out", default=None)
    ap.add_argument("--scale", choices=["fixed", "demand"], default="fixed",
                    help="fleet-scaling policy (see repro.core.policy)")
    ap.add_argument("--budget-cap", type=float, default=None,
                    help="stop scaling when this spend cap is threatened")
    args = ap.parse_args()

    from repro.core.server import Server, ServerConfig

    tasks = build_tasks(args.max_n, args.instances, args.deadline)
    print(f"{len(tasks)} tasks")
    config = ServerConfig(min_group_size=args.min_group_size,
                          max_clients=3, out_dir=args.out,
                          workers_hint=4, scale_policy=args.scale,
                          budget_cap=args.budget_cap)
    if args.engine == "sim":
        from repro.core.sim import SimCluster, SimParams

        config.use_backup = True
        cluster = SimCluster(tasks, config, SimParams(client_workers=4))
        srv = cluster.run(until=3600)
        table = srv.final_results
        print(f"simulated makespan {cluster.clock.now():.1f}s, "
              f"cost {table.cost['total']:.0f} instance-seconds "
              f"(by kind: {table.cost['by_kind']})")
    else:
        from repro.core.engine import LocalEngine

        engine = LocalEngine(n_workers_per_client=2)
        srv = Server(tasks, engine, config)
        table = srv.run(poll_sleep=0.05)
        engine.shutdown()
    solved = len(table.solved_rows())
    print(f"solved {solved}/{len(table.rows)} retained rows "
          f"(dropped groups: {len(table.dropped_groups)})")
    print("\n".join(table.to_csv().splitlines()[:12]))


if __name__ == "__main__":
    main()
