"""Persistent best-config cache for tuned kernel parameters.

Entries are keyed by ``(kernel, shape_bucket, dtype, backend)``:

* ``kernel``  — the ops-layer name (``flash_attention``, ``ssd_scan``,
  ``decode_attention``, ``decode_attention_paged``);
* ``shape_bucket`` — every shape field rounded up to a power of two
  (``b1-s256-h4-kvh2-d64``), so nearby shapes share an entry;
* ``dtype``   — the input dtype name;
* ``backend`` — the *dispatch* backend (``tpu`` / ``interpret`` / the
  jax platform name for the XLA reference path), because a block size
  tuned for the Pallas kernel says nothing about the XLA lowering.

The store is a single versioned JSON file.  Writes are atomic
(temp file in the same directory + ``os.replace``), so a crash mid-write
can never corrupt a previously-good cache.  Every entry records a hash
of the kernel's source module; a lookup against a since-edited kernel is
a miss (stale tunings are never served).  ``REPRO_TUNE_CACHE`` overrides
the cache path (empty or ``0`` disables the cache entirely); the default
lives under ``~/.cache/repro/tune_cache.json``.

This module deliberately imports nothing from ``repro`` at module level:
``kernels/ops.py`` consults it on every dispatch, so it must be cheap
and cycle-free to import.
"""
from __future__ import annotations

import contextlib
import hashlib
import importlib.util
import json
import math
import os
import tempfile

CACHE_VERSION = 1
ENV_VAR = "REPRO_TUNE_CACHE"
DEFAULT_PATH = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "tune_cache.json")

# kernel name -> module whose source hash gates entry staleness
KERNEL_MODULES = {
    "flash_attention": "repro.kernels.flash_attention",
    "ssd_scan": "repro.kernels.ssd_scan",
    "decode_attention": "repro.kernels.decode_attention",
    "decode_attention_paged": "repro.kernels.decode_attention",
}

_hash_cache: dict[str, str] = {}


def kernel_source_hash(kernel: str) -> str:
    """Short sha256 of the kernel's implementation module source.  Found
    via ``find_spec`` (no import executed) and memoized per process."""
    mod = KERNEL_MODULES.get(kernel)
    if mod is None:
        raise KeyError(f"unknown kernel {kernel!r}")
    h = _hash_cache.get(mod)
    if h is None:
        spec = importlib.util.find_spec(mod)
        with open(spec.origin, "rb") as fh:
            h = hashlib.sha256(fh.read()).hexdigest()[:12]
        _hash_cache[mod] = h
    return h


def dispatch_backend() -> str:
    """The backend family the ops layer will dispatch to right now —
    mirrors ``kernels.ops._mode`` so tuned entries only ever apply to
    the code path they were measured on."""
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env == "interpret":
        return "interpret"
    import jax

    return jax.default_backend()


def _bucket_field(v) -> int:
    v = int(v)
    if v <= 1:
        return 1
    return 1 << math.ceil(math.log2(v))


def shape_bucket(shape: dict) -> str:
    """Canonical bucket string: fields in sorted order, each rounded up
    to the next power of two."""
    return "-".join(f"{k}{_bucket_field(v)}" for k, v in
                    sorted(shape.items()))


def _entry_key(kernel: str, bucket: str, dtype: str, backend: str) -> str:
    return f"{kernel}|{backend}|{dtype}|{bucket}"


def _bucket_distance(a: dict, b: dict) -> float:
    """Log2 distance between two shape dicts; infinite when the field
    sets differ (no meaningful fallback across different workload
    identities)."""
    if set(a) != set(b):
        return float("inf")
    return sum(abs(math.log2(_bucket_field(a[k])) -
                   math.log2(_bucket_field(b[k]))) for k in a)


class TuneCache:
    """One JSON best-config store (see module docstring).  Instances
    reload from disk automatically when the file's mtime changes, so a
    long-lived process picks up a concurrent ``repro.tune`` run."""

    def __init__(self, path: str | None = None):
        self.path = path if path is not None else _env_path()
        self._entries: dict[str, dict] = {}
        self._loaded_mtime: float | None = None
        self.hits = 0
        self.misses = 0

    # -- persistence ---------------------------------------------------
    def _refresh(self) -> None:
        if not self.path:
            return
        try:
            mtime = os.stat(self.path).st_mtime_ns
        except OSError:
            self._entries, self._loaded_mtime = {}, None
            return
        if mtime == self._loaded_mtime:
            return
        try:
            with open(self.path, encoding="utf-8") as fh:
                payload = json.load(fh)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            # a corrupt cache must never break dispatch — treat as empty
            payload = {}
        if payload.get("version") != CACHE_VERSION:
            payload = {}
        self._entries = dict(payload.get("entries", {}))
        self._loaded_mtime = mtime

    def _write(self) -> None:
        payload = {"version": CACHE_VERSION, "entries": self._entries}
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tune_cache.", suffix=".tmp",
                                   dir=d)
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as fh:
                json.dump(payload, fh, indent=2, sort_keys=True)
                fh.write("\n")
            os.replace(tmp, self.path)      # atomic on POSIX
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise
        self._loaded_mtime = os.stat(self.path).st_mtime_ns

    # -- API -----------------------------------------------------------
    def store(self, kernel: str, shape: dict, dtype: str, backend: str,
              config: dict, *, runtime_us: float,
              default_us: float | None = None, meta: dict | None = None,
              ) -> str:
        """Insert/replace the best config for one key; returns the key.
        Re-reads the file first so concurrent tuners merge instead of
        clobbering each other's kernels."""
        if not self.path:
            raise RuntimeError(
                f"tune cache disabled ({ENV_VAR} is empty) — cannot store")
        self._refresh()
        bucket = shape_bucket(shape)
        key = _entry_key(kernel, bucket, dtype, backend)
        self._entries[key] = {
            "kernel": kernel, "backend": backend, "dtype": dtype,
            "bucket": bucket, "shape": {k: int(v) for k, v in shape.items()},
            "config": {k: int(v) for k, v in config.items()},
            "runtime_us": round(float(runtime_us), 3),
            "default_us": (round(float(default_us), 3)
                           if default_us is not None else None),
            "src_hash": kernel_source_hash(kernel),
            **({"meta": meta} if meta else {}),
        }
        self._write()
        return key

    def lookup(self, kernel: str, shape: dict, dtype: str,
               backend: str) -> dict | None:
        """Best config for the key, or None.  Exact bucket first, then
        the nearest bucket with the same field set (shape-bucket
        fallback); entries whose kernel source hash is stale never
        match."""
        if not self.path:
            return None
        self._refresh()
        want_hash = kernel_source_hash(kernel)
        bucket = shape_bucket(shape)
        entry = self._entries.get(_entry_key(kernel, bucket, dtype, backend))
        if entry is not None and entry.get("src_hash") == want_hash:
            self.hits += 1
            return dict(entry["config"])
        best, best_d = None, float("inf")
        for e in self._entries.values():
            if (e.get("kernel") != kernel or e.get("backend") != backend
                    or e.get("dtype") != dtype
                    or e.get("src_hash") != want_hash):
                continue
            d = _bucket_distance(shape, e.get("shape", {}))
            if d < best_d:
                best, best_d = e, d
        if best is not None:
            self.hits += 1
            return dict(best["config"])
        self.misses += 1
        return None

    def entries(self) -> dict:
        self._refresh()
        return {k: dict(v) for k, v in self._entries.items()}


# ---------------------------------------------------------------------------
# process-level singleton (what kernels/ops.py consults)
# ---------------------------------------------------------------------------
def _env_path() -> str:
    p = os.environ.get(ENV_VAR)
    if p is None:
        return DEFAULT_PATH
    if p in ("", "0"):
        return ""                  # disabled
    return p


_cache: TuneCache | None = None


def get_cache() -> TuneCache:
    """The shared cache instance, re-created when ``REPRO_TUNE_CACHE``
    changes (tests flip it per-case)."""
    global _cache
    path = _env_path()
    if _cache is None or _cache.path != path:
        _cache = TuneCache(path)
    return _cache


def reset() -> None:
    """Drop the singleton (tests)."""
    global _cache
    _cache = None
    _hash_cache.clear()


def best_config(kernel: str, shape: dict, dtype: str,
                backend: str | None = None) -> dict | None:
    """Dispatch-time lookup: the tuned config for the current backend,
    or None on any miss (absent cache, stale hash, disabled)."""
    cache = get_cache()
    if not cache.path:
        return None
    return cache.lookup(kernel, shape, dtype,
                        backend if backend is not None else
                        dispatch_backend())


__all__ = ["TuneCache", "get_cache", "reset", "best_config",
           "shape_bucket", "dispatch_backend", "kernel_source_hash",
           "CACHE_VERSION", "ENV_VAR", "KERNEL_MODULES"]
