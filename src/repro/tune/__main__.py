"""CLI: ``python -m repro.tune --kernel flash_attention --smoke``.

Runs the autotuning sweep through the Experiment facade and persists the
winning config into the best-config cache (``REPRO_TUNE_CACHE`` or
``~/.cache/repro/tune_cache.json``) that ``kernels/ops.py`` consults at
dispatch.  Exit status 1 if any sweep finished over its budget cap.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.tune.space import SPECS
from repro.tune.tuner import tune


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Autotune Pallas kernel configs through the "
                    "Experiment facade (ROADMAP item 3 dogfood).")
    ap.add_argument("--kernel", required=True,
                    choices=[*sorted(SPECS), "all"],
                    help="kernel to tune, or 'all'")
    ap.add_argument("--engine", default="sim", choices=["sim", "local"],
                    help="sim: virtual-time domino pruning from the cost "
                         "model; local: wall-clock timeouts in worker "
                         "processes (default: sim)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI shapes")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--k", type=float, default=4.0, dest="k_timeout",
                    help="timeout = k x incumbent (default 4.0)")
    ap.add_argument("--budget-cap", type=float, default=None,
                    help="CostMeter spend cap for the sweep")
    ap.add_argument("--max-clients", type=int, default=2)
    ap.add_argument("--adversarial", type=int, default=0,
                    help="seeded pathologically-bad values per knob "
                         "(exercises the domino/timeout rule)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", default=None,
                    help="cache file override (else REPRO_TUNE_CACHE / "
                         "default path)")
    ap.add_argument("--no-store", action="store_true",
                    help="report only; do not persist the winner")
    ap.add_argument("--json", default=None, dest="json_out",
                    help="also write the full reports to this file")
    args = ap.parse_args(argv)

    kernels = sorted(SPECS) if args.kernel == "all" else [args.kernel]
    reports = []
    for kern in kernels:
        rep = tune(kern, engine=args.engine, smoke=args.smoke,
                   dtype=args.dtype, k_timeout=args.k_timeout,
                   budget_cap=args.budget_cap,
                   max_clients=args.max_clients,
                   adversarial=args.adversarial, seed=args.seed,
                   cache_path=args.cache, store=not args.no_store)
        reports.append(rep)
        print(rep.summary())
        if rep.cache_key:
            print(f"  -> cached as {rep.cache_key} in {rep.cache_path}")
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump([r.to_dict() for r in reports], fh, indent=2,
                      default=float)
            fh.write("\n")
        print(f"wrote {args.json_out}")
    return 1 if any(r.under_cap is False for r in reports) else 0


if __name__ == "__main__":
    sys.exit(main())
