"""Per-kernel tunable search spaces for the autotuner.

Each Pallas kernel declares a :class:`KernelSpec`: the shape axes that
identify a workload, the tunable knobs with their candidate values, the
current dispatch defaults (``kernels/ops.py`` falls back to these on a
cache miss, so ``defaults`` here must mirror the ops-layer constants),
and a static validity predicate mirroring the kernels' divisibility
asserts — invalid configs are excluded from the grid instead of crashing
clients mid-sweep.

Hardness for the domino partial order is the **predicted cost**: a
roofline estimate (FLOPs / HBM bytes / per-grid-cell launch overhead,
same hardware model as ``launch/roofline.py``) collapsed to a single
scalar.  That makes the order total, which is exactly the
JobPruner-style "learned predictor pre-orders the grid" shape from
PAPERS.md: one config timing out prunes every config predicted to be at
least as expensive.  The same estimate drives ``sim_duration`` when the
sweep runs on the simulator engine (virtual seconds proportional to
predicted microseconds), so the paper's timeout/domino machinery applies
unchanged.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass

from repro.core.space import ParamSpace, axis

# hardware model (TPU v5e, same constants as launch/roofline.py) + a
# per-grid-cell launch overhead term — the block knobs trade this
# overhead against memory traffic, which is the whole tuning surface
PEAK_FLOPS = 197e12           # FLOP/s
HBM_BW = 819e9                # bytes/s
CELL_OVERHEAD_US = 0.2        # per pallas grid cell

# virtual seconds per predicted microsecond when the sweep runs on the
# simulator engine (pure scale factor: timeouts are k x incumbent in the
# same unit, so the choice only affects readability of the virtual clock)
SIM_SECONDS_PER_US = 0.05

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class KernelSpec:
    """Tunable surface of one kernel (see module docstring)."""

    name: str
    shape_axes: tuple               # ordered workload-identity fields
    smoke_shape: dict               # small CI shape
    full_shape: dict                # representative shape
    defaults: dict                  # tunable -> current dispatch default
    tunables: dict                  # tunable -> candidate values
    pathological: dict              # tunable -> adversarially bad values

    @property
    def tunable_names(self) -> tuple:
        return tuple(self.tunables)


SPECS: dict[str, KernelSpec] = {
    "flash_attention": KernelSpec(
        name="flash_attention",
        shape_axes=("b", "s", "h", "kvh", "d"),
        smoke_shape={"b": 1, "s": 256, "h": 4, "kvh": 2, "d": 64},
        full_shape={"b": 1, "s": 1024, "h": 8, "kvh": 2, "d": 64},
        defaults={"block_q": 128, "block_k": 128},
        tunables={"block_q": (64, 128, 256), "block_k": (64, 128, 256)},
        pathological={"block_q": (8, 16), "block_k": (8, 16)},
    ),
    "ssd_scan": KernelSpec(
        name="ssd_scan",
        shape_axes=("b", "s", "h", "p", "g", "n"),
        smoke_shape={"b": 1, "s": 512, "h": 2, "p": 64, "g": 1, "n": 32},
        full_shape={"b": 1, "s": 2048, "h": 4, "p": 64, "g": 1, "n": 64},
        defaults={"chunk": 64},
        # >= 3 pathological values: with max_clients concurrent timeouts
        # at least one is still queued when the first fires, so the
        # domino rule provably prunes (not just times out) on the
        # adversarial grid
        tunables={"chunk": (32, 64, 128, 256)},
        pathological={"chunk": (2, 4, 8)},
    ),
    "decode_attention": KernelSpec(
        name="decode_attention",
        shape_axes=("b", "sk", "h", "kvh", "d"),
        smoke_shape={"b": 4, "sk": 512, "h": 4, "kvh": 2, "d": 64},
        full_shape={"b": 16, "sk": 2048, "h": 8, "kvh": 2, "d": 64},
        defaults={"block_k": 128},
        tunables={"block_k": (64, 128, 256, 512)},
        pathological={"block_k": (8, 16)},
    ),
    "decode_attention_paged": KernelSpec(
        name="decode_attention_paged",
        shape_axes=("b", "sk", "kvh", "g", "d"),
        smoke_shape={"b": 4, "sk": 256, "kvh": 2, "g": 2, "d": 64},
        full_shape={"b": 16, "sk": 2048, "kvh": 2, "g": 4, "d": 64},
        defaults={"page_size": 16},
        tunables={"page_size": (8, 16, 32, 64, 128)},
        pathological={"page_size": (1, 2)},
    ),
}


# ---------------------------------------------------------------------------
# static validity (mirrors the kernels' divisibility asserts)
# ---------------------------------------------------------------------------
def valid(kernel: str, cell: dict) -> bool:
    """True iff the config satisfies the kernel's static constraints —
    mirrored from the kernels' own divisibility asserts so bad configs
    are rejected before any client process touches them."""
    if kernel == "flash_attention":
        s = cell["s"]
        bq, bk = min(cell["block_q"], s), min(cell["block_k"], s)
        return bq > 0 and bk > 0 and s % bq == 0 and s % bk == 0
    if kernel == "ssd_scan":
        s, c = cell["s"], min(cell["chunk"], cell["s"])
        return c > 0 and s % c == 0
    if kernel == "decode_attention":
        # the wrapper zero-pads Sk up to a block multiple, so any
        # positive block is statically valid (padding waste is costed)
        return cell["block_k"] > 0
    if kernel == "decode_attention_paged":
        return 0 < cell["page_size"] <= cell["sk"]
    raise KeyError(f"unknown kernel {kernel!r} (have {sorted(SPECS)})")


# ---------------------------------------------------------------------------
# predicted cost (roofline estimate, microseconds)
# ---------------------------------------------------------------------------
def predicted_cost_us(kernel: str, cell: dict) -> float:
    """Roofline cost estimate in microseconds for one kernel call.

    compute = FLOPs / peak, memory = HBM bytes (including block-dependent
    K/V re-reads and padding waste), overhead = grid cells x launch cost.
    Monotone in the right directions: tiny blocks blow up the overhead
    and re-read terms, huge chunks blow up the intra-chunk quadratic
    term — which is what makes it a usable hardness ordering.
    """
    eb = _DTYPE_BYTES.get(cell.get("dtype", "float32"), 4)
    if kernel == "flash_attention":
        b, s, h, kvh, d = (cell[k] for k in ("b", "s", "h", "kvh", "d"))
        bq = min(cell["block_q"], s)
        bk = min(cell["block_k"], s)
        nq, nk = _ceil_div(s, bq), _ceil_div(s, bk)
        flops = 4.0 * b * s * s * h * d * 0.5          # causal halves it
        qo_bytes = 2.0 * b * s * h * d * eb
        kv_bytes = 2.0 * b * s * kvh * d * eb * nq     # re-read per q row
        cells = b * h * nq * nk
    elif kernel == "ssd_scan":
        b, s, h, p, g, n = (cell[k] for k in
                            ("b", "s", "h", "p", "g", "n"))
        length = min(cell["chunk"], s)
        nc = _ceil_div(s, length)
        flops = b * h * nc * (2.0 * length * length * (n + p)
                              + 4.0 * length * n * p)
        qo_bytes = 2.0 * b * s * h * p * eb + 2.0 * b * s * g * 2 * n * eb
        kv_bytes = b * h * nc * p * n * 4 * 2.0        # fp32 state traffic
        cells = b * h * nc
    elif kernel == "decode_attention":
        b, sk, h, kvh, d = (cell[k] for k in ("b", "sk", "h", "kvh", "d"))
        bk = min(cell["block_k"], sk)
        nk = _ceil_div(sk, bk)
        skp = nk * bk                                  # padding waste
        flops = 4.0 * b * sk * h * d
        qo_bytes = 2.0 * b * h * d * eb
        kv_bytes = 2.0 * b * skp * kvh * d * eb
        cells = b * kvh * nk
    elif kernel == "decode_attention_paged":
        b, sk, kvh, g, d = (cell[k] for k in ("b", "sk", "kvh", "g", "d"))
        ps = cell["page_size"]
        w = _ceil_div(sk, ps)
        flops = 4.0 * b * sk * kvh * g * d
        qo_bytes = 2.0 * b * kvh * g * d * eb
        kv_bytes = 2.0 * b * w * ps * kvh * d * eb
        cells = b * kvh * w
    else:
        raise KeyError(f"unknown kernel {kernel!r} (have {sorted(SPECS)})")
    return (flops / PEAK_FLOPS * 1e6
            + (qo_bytes + kv_bytes) / HBM_BW * 1e6
            + cells * CELL_OVERHEAD_US)


def hardness_of(kernel: str, cell: dict) -> tuple:
    """1-tuple hardness: predicted cost.  A total order — one timeout
    domino-prunes everything predicted at least as expensive."""
    return (predicted_cost_us(kernel, cell),)


def sim_duration_s(kernel: str, cell: dict) -> float:
    """Virtual runtime on the simulator engine (predicted microseconds
    scaled to virtual seconds)."""
    return predicted_cost_us(kernel, cell) * SIM_SECONDS_PER_US


# ---------------------------------------------------------------------------
# grid construction
# ---------------------------------------------------------------------------
def candidate_values(spec: KernelSpec, shape: dict, *, adversarial: int = 0,
                     seed: int = 0) -> dict:
    """Per-tunable candidate lists: the declared candidates filtered for
    static validity against ``shape`` (defaults always included), plus
    ``adversarial`` seeded draws from the pathological pool — the
    deliberately bad configs the CI smoke grid uses to prove the
    domino/timeout rule fires."""
    rnd = random.Random(seed)
    out = {}
    for name, cands in spec.tunables.items():
        vals = list(dict.fromkeys((spec.defaults[name], *cands)))
        if adversarial:
            pool = list(spec.pathological.get(name, ()))
            rnd.shuffle(pool)
            vals.extend(pool[:adversarial])
        kept = []
        for v in vals:
            cell = {**shape, **spec.defaults, name: v}
            if valid(spec.name, cell):
                kept.append(v)
        out[name] = tuple(dict.fromkeys(kept))
    return out


def build_space(kernel: str, shape: dict | None = None, *, smoke: bool = False,
                dtype: str = "float32", adversarial: int = 0,
                seed: int = 0) -> ParamSpace:
    """The sweep grid for one kernel: shape fields are fixed single-value
    axes (they appear in the results table, so every row is
    self-describing), tunables are real axes.  Cross-knob validity is
    enforced with a dependent domain on the last tunable axis, so the
    expanded grid contains no statically-invalid cell."""
    spec = SPECS[kernel]
    shape = dict(shape or (spec.smoke_shape if smoke else spec.full_shape))
    missing = [a for a in spec.shape_axes if a not in shape]
    if missing:
        raise ValueError(f"shape for {kernel} is missing axes {missing}")
    cands = candidate_values(spec, {**shape, "dtype": dtype},
                             adversarial=adversarial, seed=seed)
    axes: dict = {a: (shape[a],) for a in spec.shape_axes}
    axes["dtype"] = (dtype,)
    names = list(spec.tunable_names)
    for name in names[:-1]:
        axes[name] = axis(cands[name])
    last = names[-1]

    def _last_domain(cell, _k=kernel, _last=last, _vals=cands[last]):
        return tuple(v for v in _vals if valid(_k, {**cell, _last: v}))

    axes[last] = axis(_last_domain)
    return ParamSpace.grid(**axes)


def next_pow2(v: int) -> int:
    return 1 << max(0, math.ceil(math.log2(v))) if v > 1 else 1


__all__ = ["KernelSpec", "SPECS", "valid", "predicted_cost_us",
           "hardness_of", "sim_duration_s", "candidate_values",
           "build_space", "next_pow2", "SIM_SECONDS_PER_US"]
