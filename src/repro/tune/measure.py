"""Measurement primitives shared by the autotuner and the benchmarks.

Two concerns live here, both motivated by Gent & Kotthoff's virtualised-
hardware reliability results (PAPERS.md): a single wall-clock sample on a
shared machine is not a measurement.

* :func:`time_fn` — warm up exactly once (compile included), then take
  repeated samples and reject the slow outliers (GC pauses, noisy
  neighbours) before averaging.
* :func:`retry_measurement` — the noisy-runner guard the smoke-floor
  benchmarks share: keep the first measurement when it passes, otherwise
  re-run a bounded number of times, recording every repeat in the
  artifact so flakiness is visible instead of silently absorbed.
  (Moved here from ``benchmarks/sim_scale_bench.py`` so library code can
  reuse it; the benchmarks import it from this module.)
"""
from __future__ import annotations

import math
import time


def robust_mean_us(samples_us: list[float], outlier_frac: float = 0.25):
    """Mean of the samples after dropping the slowest ``outlier_frac``
    share (at least one sample is always kept).  Returns ``(mean, kept)``
    so callers can report how many samples survived rejection."""
    if not samples_us:
        raise ValueError("no samples")
    keep = max(1, math.ceil(len(samples_us) * (1.0 - outlier_frac)))
    kept = sorted(samples_us)[:keep]
    return sum(kept) / len(kept), len(kept)


def time_fn(fn, *args, iters: int = 5, outlier_frac: float = 0.25):
    """Time ``fn(*args)`` in microseconds: one warmup call (compile +
    cache fill — the result is blocked on but never re-computed for the
    warmup, see the kernel_bench double-call bug this replaces), then
    ``iters`` blocked samples, outlier-rejected via :func:`robust_mean_us`.

    Returns ``(mean_us, n_kept, samples_us)``.  Works on any callable
    returning a jax pytree (``jax.block_until_ready`` accepts pytrees,
    including tuples) or plain Python values.
    """
    import jax

    out = fn(*args)
    jax.block_until_ready(out)           # the one warmup call
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    mean, kept = robust_mean_us(samples, outlier_frac)
    return mean, kept, samples


def retry_measurement(out: dict, label: str, first, measure, accept, best,
                      retries: int = 1):
    """Noisy-runner guard shared by every smoke-floor measurement.

    Keeps ``first`` when ``accept`` passes; otherwise re-runs ``measure``
    up to ``retries`` times, folding each repeat in with ``best`` (``max``
    for scalars, an argmax lambda for records) and appending it under
    ``out["retries"][label]`` — the artifact shows exactly how flaky the
    runner was instead of silently absorbing it."""
    result = first
    for _ in range(retries):
        if accept(result):
            break
        again = measure()
        out.setdefault("retries", {}).setdefault(label, []).append(again)
        result = best(result, again)
    return result


__all__ = ["robust_mean_us", "time_fn", "retry_measurement"]
