"""``repro.tune`` — kernel autotuning driven through the Experiment
facade, with a persistent best-config cache wired into ops dispatch.

Submodules: ``space`` (per-kernel tunable grids + roofline cost model),
``runner`` (measurement ``@task``s), ``cache`` (persistent best-config
store), ``tuner`` (the sweep orchestration), ``measure`` (shared timing
utilities).  CLI: ``python -m repro.tune --kernel flash_attention
--smoke``.

Heavy submodules load lazily: ``kernels/ops.py`` imports
``repro.tune.cache`` on its hot dispatch path, which must not drag the
Experiment facade (or jax tracing machinery) in behind it.
"""
from __future__ import annotations

_LAZY = {
    "tune": ("repro.tune.tuner", "tune"),
    "tune_all": ("repro.tune.tuner", "tune_all"),
    "TuneReport": ("repro.tune.tuner", "TuneReport"),
    "TuneCache": ("repro.tune.cache", "TuneCache"),
    "best_config": ("repro.tune.cache", "best_config"),
    "SPECS": ("repro.tune.space", "SPECS"),
    "build_space": ("repro.tune.space", "build_space"),
    "predicted_cost_us": ("repro.tune.space", "predicted_cost_us"),
    "retry_measurement": ("repro.tune.measure", "retry_measurement"),
    "time_fn": ("repro.tune.measure", "time_fn"),
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    try:
        mod, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib

    return getattr(importlib.import_module(mod), attr)
