"""Measurement tasks: compile + time one kernel config.

One module-level ``@task`` function per kernel (module-level so
``FunctionTask`` pickles by reference and the sweep can run on the
LocalEngine's worker processes).  Each task:

* statically re-validates the config (``space.valid``) and raises
  ``ValueError`` *before* building any inputs — a config that slipped
  past the grid filter is rejected loudly instead of tripping a kernel
  assert deep inside a client;
* builds seeded inputs for the cell's shape, then times the call
  **through ``kernels/ops.py`` dispatch** (never bypassing it — the
  measurement exercises exactly the code path a model would hit on this
  backend, Pallas kernel / interpret / XLA reference alike);
* warms up exactly once and takes repeated outlier-rejected samples
  (:func:`repro.tune.measure.time_fn`) — virtualised-hardware timing
  noise is rejected, not averaged in.

Every task declares ``hardness`` and ``sim_duration`` from the roofline
predicted cost (``repro.tune.space``), which is what lets the sweep run
through ``Experiment(engine="sim")`` with the paper's timeout/domino
pruning fully active: a config whose *predicted* virtual runtime blows
the timeout is killed and domino-prunes everything predicted harder,
without the host ever paying for the measurement.

Returns ``(runtime_us, n_kept, n_samples)`` per config.
"""
from __future__ import annotations

import functools

from repro.core.space import task
from repro.tune import space as _space
from repro.tune.measure import time_fn

RESULT_TITLES = ("runtime_us", "n_kept", "n_samples")
MEASURE_ITERS = 5


def _check(kernel: str, cell: dict) -> None:
    if not _space.valid(kernel, cell):
        raise ValueError(
            f"invalid {kernel} config {cell!r}: violates the kernel's "
            f"divisibility constraints (should have been filtered "
            f"statically by repro.tune.space.build_space)")


def _keys(*ks):
    import jax

    return jax.random.split(jax.random.PRNGKey(0), len(ks))


def _normal(key, shape, dtype):
    import jax

    return jax.random.normal(key, shape, dtype)


def _hard(kernel):
    def h(**cell):
        return _space.hardness_of(kernel, cell)
    return h


def _simdur(kernel):
    def s(**cell):
        return _space.sim_duration_s(kernel, cell)
    return s


def _timed(fn, *args):
    mean_us, kept, samples = time_fn(fn, *args, iters=MEASURE_ITERS)
    return mean_us, kept, len(samples)


@task(result_titles=RESULT_TITLES, hardness=_hard("flash_attention"),
      sim_duration=_simdur("flash_attention"))
def measure_flash_attention(b, s, h, kvh, d, dtype, block_q, block_k):
    cell = dict(b=b, s=s, h=h, kvh=kvh, d=d, dtype=dtype,
                block_q=block_q, block_k=block_k)
    _check("flash_attention", cell)
    import jax.numpy as jnp

    from repro.kernels import ops

    dt = jnp.dtype(dtype)
    kq, kk, kv = _keys("q", "k", "v")
    q = _normal(kq, (b, s, h, d), dt)
    k = _normal(kk, (b, s, kvh, d), dt)
    v = _normal(kv, (b, s, kvh, d), dt)
    fn = functools.partial(ops.flash_attention, causal=True,
                           block_q=block_q, block_k=block_k)
    return _timed(fn, q, k, v)


@task(result_titles=RESULT_TITLES, hardness=_hard("ssd_scan"),
      sim_duration=_simdur("ssd_scan"))
def measure_ssd_scan(b, s, h, p, g, n, dtype, chunk):
    cell = dict(b=b, s=s, h=h, p=p, g=g, n=n, dtype=dtype, chunk=chunk)
    _check("ssd_scan", cell)
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    dt = jnp.dtype(dtype)
    kx, kt, ka, kb, kc = _keys("x", "t", "a", "b", "c")
    x = _normal(kx, (b, s, h, p), dt)
    dtv = jax.nn.softplus(_normal(kt, (b, s, h), jnp.float32)).astype(dt)
    A = -jnp.exp(_normal(ka, (h,), jnp.float32) * 0.3)
    Bm = _normal(kb, (b, s, g, n), dt)
    Cm = _normal(kc, (b, s, g, n), dt)
    fn = functools.partial(ops.ssd_scan, chunk=chunk)
    return _timed(fn, x, dtv, A, Bm, Cm)


@task(result_titles=RESULT_TITLES, hardness=_hard("decode_attention"),
      sim_duration=_simdur("decode_attention"))
def measure_decode_attention(b, sk, h, kvh, d, dtype, block_k):
    cell = dict(b=b, sk=sk, h=h, kvh=kvh, d=d, dtype=dtype,
                block_k=block_k)
    _check("decode_attention", cell)
    import jax.numpy as jnp

    from repro.kernels import ops

    dt = jnp.dtype(dtype)
    kq, kk, kv = _keys("q", "k", "v")
    q = _normal(kq, (b, h, d), dt)
    k = _normal(kk, (b, sk, kvh, d), dt)
    v = _normal(kv, (b, sk, kvh, d), dt)
    # ragged fill levels, the serving steady state (deterministic)
    kv_len = jnp.asarray([sk - (i * sk // (2 * b)) for i in range(b)],
                         jnp.int32)
    fn = functools.partial(ops.decode_attention, block_k=block_k)
    return _timed(fn, q, k, v, kv_len)


@task(result_titles=RESULT_TITLES,
      hardness=_hard("decode_attention_paged"),
      sim_duration=_simdur("decode_attention_paged"))
def measure_decode_attention_paged(b, sk, kvh, g, d, dtype, page_size):
    cell = dict(b=b, sk=sk, kvh=kvh, g=g, d=d, dtype=dtype,
                page_size=page_size)
    _check("decode_attention_paged", cell)
    import jax.numpy as jnp

    from repro.kernels import ops

    dt = jnp.dtype(dtype)
    w = -(-sk // page_size)                 # pages per slot
    n_pages = b * w
    kq, kk, kv = _keys("q", "k", "v")
    q = _normal(kq, (b, kvh * g, d), dt)
    k_pool = _normal(kk, (n_pages, page_size, kvh, d), dt)
    v_pool = _normal(kv, (n_pages, page_size, kvh, d), dt)
    # each slot owns a contiguous page run, shuffled per-slot order is
    # exercised by the serve tests — here geometry cost is the question
    page_table = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, w)
    kv_len = jnp.asarray([sk - (i * sk // (2 * b)) for i in range(b)],
                         jnp.int32)
    return _timed(ops.decode_attention_paged, q, k_pool, v_pool,
                  page_table, kv_len)


MEASURE_TASKS = {
    "flash_attention": measure_flash_attention,
    "ssd_scan": measure_ssd_scan,
    "decode_attention": measure_decode_attention,
    "decode_attention_paged": measure_decode_attention_paged,
}


def measure_cell(kernel: str, cell: dict):
    """Measure one fully-specified cell inline (the tuner's incumbent
    measurement) — same code path as the sweep tasks."""
    return MEASURE_TASKS[kernel].fn(**cell)


__all__ = ["MEASURE_TASKS", "measure_cell", "RESULT_TITLES",
           "MEASURE_ITERS", "measure_flash_attention", "measure_ssd_scan",
           "measure_decode_attention", "measure_decode_attention_paged"]
