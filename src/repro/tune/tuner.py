"""Kernel autotuning driven through the ``Experiment`` facade.

This is ROADMAP item 3 — the repo as its own first production user: the
sweep over kernel configs is just another parameter-space exploration,
so it runs through exactly the machinery the paper built for them:

* the grid is a ``ParamSpace`` (``repro.tune.space``), hardness = the
  roofline predicted cost (a total order — the JobPruner shape);
* every config is a ``@task`` (``repro.tune.runner``) with
  ``timeout = k x incumbent``, so the paper's timeout/domino rule prunes
  configs that cannot beat the incumbent — on ``engine="sim"`` the
  virtual runtime *is* the predicted cost, so pruning costs the host
  nothing; on ``engine="local"`` the timeout is wall-clock and kills the
  measurement process for real;
* ``budget_cap=`` flows straight into ``BudgetPolicy``/``CostMeter``,
  and the per-config attributed cost comes back on the results table —
  the paper's budget story applied to the dogfood workload;
* the winner is persisted into the :mod:`repro.tune.cache` store, which
  ``kernels/ops.py`` consults at dispatch — every future call on this
  backend/shape bucket picks the tuned config up automatically.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field

from repro.core.experiment import Experiment
from repro.core.scheduler import DONE, PRUNED, TIMED_OUT
from repro.tune import cache as _cache
from repro.tune import runner as _runner
from repro.tune import space as _space

# wall-clock slack added to local-engine timeouts: a cold worker process
# pays the full jax import + jit compile before its first sample, which
# the incumbent measurement (in-process, already warm) did not
LOCAL_COMPILE_MARGIN_S = 10.0


@dataclass
class TuneReport:
    """Typed outcome of one tuning sweep."""

    kernel: str
    backend: str
    dtype: str
    shape: dict
    shape_bucket: str
    engine: str
    k_timeout: float
    timeout_s: float
    explored: int                    # grid cells submitted
    measured: int                    # DONE: actually compiled + timed
    timed_out: int
    pruned: int                      # domino-pruned, never ran
    default_config: dict
    default_us: float
    best_config: dict
    best_us: float
    speedup: float                   # default_us / best_us (>= 1.0)
    pruned_fraction: float           # (pruned + timed_out) / explored
    budget_cap: float | None
    cost_total: float | None         # CostMeter total for the sweep
    under_cap: bool | None           # None when no cap was set
    cache_path: str | None
    cache_key: str | None
    elapsed_s: float
    configs: list = field(default_factory=list)   # per-config records

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, default=float)

    def summary(self) -> str:
        cap = ("n/a" if self.budget_cap is None else
               f"{self.cost_total:.2f}/{self.budget_cap:.0f} "
               f"({'under' if self.under_cap else 'OVER'} cap)")
        return (f"{self.kernel:24s} [{self.backend}/{self.dtype}] "
                f"{self.shape_bucket}: best={self.best_config} "
                f"{self.best_us:.0f}us vs default {self.default_us:.0f}us "
                f"({self.speedup:.2f}x) | explored={self.explored} "
                f"measured={self.measured} timed_out={self.timed_out} "
                f"pruned={self.pruned} | cost {cap}")


def _config_of(cell: dict, tunables: tuple) -> dict:
    return {k: cell[k] for k in tunables}


def _measure_entry(kernel: str, cell: dict, q) -> None:
    """Spawned-subprocess target: measure one cell, ship the result back."""
    from repro.tune import runner

    q.put(runner.measure_cell(kernel, cell))


def _measure_incumbent(kernel: str, cell: dict, engine: str):
    """Measure the incumbent config.  On ``engine="local"`` this runs in
    a *spawned* subprocess: the LocalEngine forks its client processes,
    and a parent that has already initialised jax (multithreaded) would
    hand every forked client a deadlocked runtime — the tuner parent must
    stay jax-free until the sweep is over."""
    if engine != "local":
        return _runner.measure_cell(kernel, cell)
    import multiprocessing

    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    p = ctx.Process(target=_measure_entry, args=(kernel, cell, q))
    p.start()
    try:
        result = q.get(timeout=300.0)
    finally:
        p.join(timeout=10.0)
        if p.is_alive():
            p.kill()
    return result


def tune(kernel: str, *, shape: dict | None = None, dtype: str = "float32",
         engine: str = "sim", k_timeout: float = 4.0,
         budget_cap: float | None = None, max_clients: int = 2,
         smoke: bool = False, adversarial: int = 0, seed: int = 0,
         cache_path: str | None = None, store: bool = True) -> TuneReport:
    """Tune one kernel and (optionally) persist the winner.

    ``engine="sim"`` runs the sweep on the simulator: virtual runtimes
    are the predicted costs, so timeout/domino pruning is decided by the
    cost model and only surviving configs are actually measured on the
    host.  ``engine="local"`` runs each measurement in a worker process
    under a real wall-clock timeout.  ``adversarial`` injects that many
    seeded pathologically-bad values per knob (CI uses this to prove the
    domino rule fires).  ``store=False`` skips cache persistence.
    """
    t_wall = time.time()
    if kernel not in _space.SPECS:
        raise ValueError(
            f"unknown kernel {kernel!r}; tunable kernels: "
            f"{sorted(_space.SPECS)}")
    spec = _space.SPECS[kernel]
    shape = dict(shape or (spec.smoke_shape if smoke else spec.full_shape))
    cache = (_cache.TuneCache(cache_path) if cache_path is not None
             else _cache.get_cache())

    # ---- incumbent: the current dispatch default ----------------------
    # (in a spawned subprocess on the local engine — see
    # _measure_incumbent; the backend probe is deferred past the sweep
    # for the same reason, it initialises jax)
    default_cell = {**shape, "dtype": dtype, **spec.defaults}
    default_us, _, _ = _measure_incumbent(kernel, default_cell, engine)

    # ---- the sweep, through the facade --------------------------------
    sp = _space.build_space(kernel, shape, dtype=dtype,
                            adversarial=adversarial, seed=seed)
    if engine == "sim":
        # virtual seconds: timeout is k x the incumbent's *predicted*
        # cost, in the same unit as every task's sim_duration
        timeout_s = k_timeout * _space.sim_duration_s(kernel, default_cell)
    else:
        timeout_s = k_timeout * default_us / 1e6 + LOCAL_COMPILE_MARGIN_S
    tasks = sp.bind(_runner.MEASURE_TASKS[kernel]).tasks(timeout=timeout_s)
    # easiest-first, the paper's execution order for the domino rule
    tasks.sort(key=lambda t: t.hardness_parameters())

    exp = Experiment(tasks, engine=engine, max_clients=max_clients,
                     budget_cap=budget_cap)
    with exp.run() as run:
        table = run.results()
    backend = _cache.dispatch_backend()

    # ---- results ------------------------------------------------------
    titles = table.parameter_titles
    tunables = spec.tunable_names
    configs = []
    best_us, best_config = default_us, dict(spec.defaults)
    n_done = n_pruned = n_timed = 0
    for i, (params, result, status) in enumerate(table.rows):
        cell = dict(zip(titles, params, strict=True))
        cfg = _config_of(cell, tunables)
        row_cost = (table.row_costs[i]
                    if table.row_costs is not None else None)
        rec = {"config": cfg, "status": status,
               "predicted_us": round(
                   _space.predicted_cost_us(kernel, cell), 3),
               "cost": row_cost}
        if status == DONE and result is not None:
            n_done += 1
            rt = float(result[0])
            rec["runtime_us"] = round(rt, 3)
            if rt < best_us:
                best_us, best_config = rt, cfg
        elif status == TIMED_OUT:
            n_timed += 1
        elif status == PRUNED:
            n_pruned += 1
        configs.append(rec)

    cost_total = (table.cost or {}).get("total")
    under_cap = (None if budget_cap is None
                 else (cost_total is not None and cost_total <= budget_cap))
    cache_key = None
    if store and cache.path:
        cache_key = cache.store(
            kernel, shape, dtype, backend, best_config,
            runtime_us=best_us, default_us=default_us,
            meta={"engine": engine, "explored": len(tasks),
                  "pruned": n_pruned, "timed_out": n_timed})
    explored = len(tasks)
    return TuneReport(
        kernel=kernel, backend=backend, dtype=dtype, shape=shape,
        shape_bucket=_cache.shape_bucket(shape), engine=engine,
        k_timeout=k_timeout, timeout_s=timeout_s, explored=explored,
        measured=n_done, timed_out=n_timed, pruned=n_pruned,
        default_config=dict(spec.defaults), default_us=default_us,
        best_config=best_config, best_us=best_us,
        speedup=(default_us / best_us if best_us > 0 else 1.0),
        pruned_fraction=((n_pruned + n_timed) / explored
                         if explored else 0.0),
        budget_cap=budget_cap, cost_total=cost_total, under_cap=under_cap,
        cache_path=(cache.path or None) if store else None,
        cache_key=cache_key, elapsed_s=round(time.time() - t_wall, 3),
        configs=configs,
    )


def tune_all(kernels=None, **kw) -> list[TuneReport]:
    """Tune several kernels with shared options (CLI ``--kernel all``)."""
    return [tune(k, **kw) for k in (kernels or sorted(_space.SPECS))]


__all__ = ["tune", "tune_all", "TuneReport", "LOCAL_COMPILE_MARGIN_S"]
