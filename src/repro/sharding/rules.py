"""Logical-axis sharding rules.

Model code annotates parameters and activations with *logical* axis names
('batch', 'heads', 'ffn', 'experts', 'vocab', ...).  A ``ShardingRules``
object (built from a concrete mesh) resolves logical names to physical mesh
axes, dropping any axis whose dimension is not divisible by the mesh axes it
maps to (e.g. granite-20b's single KV head cannot be sharded over model=16
and silently falls back to replication — the Megatron/MaxText convention).

Rules are installed with ``use_rules(rules)``; model code calls
``shard(x, *logical)`` which is a no-op when no rules are installed
(single-device smoke tests).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# Default logical->physical tables.  'pod' participates in the batch axes on
# the multi-pod mesh (outer data parallelism across pods).
def default_table(mesh: Mesh, seq_shard: bool = False) -> dict:
    axes = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = ("model",) if "model" in axes else ()
    table = {
        "batch": dp,
        "seq": (),          # sequence usually replicated ...
        "seq_kv": (),       # ... unless sequence sharding is on
        "vocab": tp,
        "heads": tp,
        "kv_heads": tp,
        "ffn": tp,
        "experts": tp,
        "embed": (),
        "model_dim": (),    # alias of embed for activations
        "state": (),
        "layers": (),
        "q_lora": (),
        "kv_lora": (),
        "codebooks": (),
    }
    if seq_shard:
        # long-context cells: batch < data-axis size -> shard sequence on data
        table["seq"] = ("data",)
        table["seq_kv"] = ("data",)
        table["batch"] = tuple(a for a in dp if a != "data")
    return table


@dataclass
class ShardingRules:
    mesh: Mesh
    table: dict = field(default_factory=dict)

    def axis_size(self, phys: tuple[str, ...]) -> int:
        return math.prod(self.mesh.shape[a] for a in phys)

    def spec(self, logical, shape=None) -> PartitionSpec:
        """Resolve a logical spec (tuple of names/None) to a PartitionSpec.

        If ``shape`` is given, drop mesh axes that don't divide the dim.
        """
        out = []
        for i, name in enumerate(logical):
            if shape is not None and i >= len(shape):
                break  # caller passed more names than dims (e.g. 2-D path
                       # through a 3-D helper); extra names are moot
            if name is None:
                out.append(None)
                continue
            phys = self.table.get(name, ())
            if not phys:
                out.append(None)
                continue
            if shape is not None \
                    and shape[i] % self.axis_size(phys) != 0:
                out.append(None)
                continue
            out.append(phys[0] if len(phys) == 1 else phys)
        # PartitionSpec forbids repeating a mesh axis; guard against tables
        # that would double-use one (can happen with custom tables).
        seen: set[str] = set()
        clean = []
        for entry in out:
            names = (entry,) if isinstance(entry, str) else (entry or ())
            if any(n in seen for n in names):
                clean.append(None)
            else:
                seen.update(names)
                clean.append(entry)
        return PartitionSpec(*clean)

    def sharding(self, logical, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


_current: contextvars.ContextVar[ShardingRules | None] = contextvars.ContextVar(
    "sharding_rules", default=None
)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    tok = _current.set(rules)
    try:
        yield rules
    finally:
        _current.reset(tok)


def current_rules() -> ShardingRules | None:
    return _current.get()


def make_rules(mesh: Mesh, seq_shard: bool = False, **overrides) -> ShardingRules:
    table = default_table(mesh, seq_shard=seq_shard)
    table.update(overrides)
    return ShardingRules(mesh=mesh, table=table)


def shard(x, *logical):
    """Constrain an activation's sharding by logical axis names.

    No-op when no rules are installed or the name resolves to nothing.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(logical, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
