"""Beyond-paper distributed-optimization trick: int8 error-feedback
gradient all-reduce.

On the production mesh, gradients are all-reduced over ('pod', 'data') by
XLA as a byproduct of SPMD autodiff.  For DCI-limited multi-pod training the
cross-pod reduction can be compressed: quantise grads to int8 with a
per-tensor scale, all-reduce the int8 payload (4x fewer bytes over the
slow links), dequantise, and keep the quantisation residual locally
(error feedback, Karimireddy et al. 2019) so compression noise becomes a
*delayed* rather than *lost* signal.

Implemented as a grad-transform usable in two modes:
  * `simulate_quantize` — pure per-tensor fake-quant + error feedback
    (works under pjit; the all-reduce stays XLA's, bytes savings are
    modelled in the roofline, not realised on CPU).
  * `shard_map_allreduce_int8` — explicit shard_map psum over a named axis
    of the int8 payload (the real collective layout; exercised in tests on
    a host-device mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quant(x, bits: int = 8):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    qmax = 2.0 ** (bits - 1) - 1
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def make_error_feedback_compress(descr_like):
    """Returns (init_fn, transform) where transform(grads, residuals) ->
    (compressed_grads, new_residuals)."""

    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def transform(grads, residuals):
        def per(g, r):
            gf = g.astype(jnp.float32) + r
            q, scale = _quant(gf)
            deq = _dequant(q, scale)
            return deq.astype(g.dtype), gf - deq

        out = jax.tree_util.tree_map(per, grads, residuals)
        is_pair = lambda t: isinstance(t, tuple)
        new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_pair)
        new_r = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_pair)
        return new_g, new_r

    return init, transform


def allreduce_int8(x, axis_name: str):
    """Explicit compressed all-reduce of one tensor over a mesh axis.

    Quantises locally, psums the int8 payload as int32 (saturation-safe for
    <= 2^23 participants), rescales by the max scale.  Call inside
    shard_map with the DP axes named.
    """
    q, scale = _quant(x)
    scale = jax.lax.pmax(scale, axis_name)  # common scale: max over ranks
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(x.dtype)
