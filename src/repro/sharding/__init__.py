from repro.sharding.rules import (ShardingRules, current_rules, make_rules,
                                  shard, use_rules)

__all__ = ["ShardingRules", "current_rules", "make_rules", "shard",
           "use_rules"]
