"""ZeRO-1: shard optimizer state over the data-parallel axes.

Parameters are TP-sharded over 'model'; their optimizer moments (and fp32
master copies) are additionally sharded over the DP axes ('pod','data') on
the first divisible unsharded dimension.  This is what makes Adam states of
a 671B model representable: state bytes/device scale with
1/(model_parallel * data_parallel) instead of 1/model_parallel.
"""
from __future__ import annotations

import math

from jax.sharding import NamedSharding, PartitionSpec

from repro.models.params import Param, tree_map, is_param
from repro.sharding.rules import ShardingRules


def _dp_axes(rules: ShardingRules) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in rules.mesh.axis_names)


def zero1_spec(spec: PartitionSpec, shape, rules: ShardingRules) -> PartitionSpec:
    """Add the DP axes to the first unsharded, divisible dim of ``spec``."""
    dp = _dp_axes(rules)
    if not dp:
        return spec
    dp_size = math.prod(rules.mesh.shape[a] for a in dp)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, d) in enumerate(zip(entries, shape, strict=False)):
        if e is None and d % dp_size == 0:
            entries[i] = dp if len(dp) > 1 else dp[0]
            return PartitionSpec(*entries)
    return spec


def _normalize(spec: PartitionSpec, ndim: int) -> list:
    return list(spec) + [None] * (ndim - len(spec))


def adamw_state_shardings(descr_tree, rules: ShardingRules, zero1: bool = True):
    """Sharding tree matching AdamW.init's state structure."""

    def per(p: Param):
        spec = rules.spec(p.logical, p.shape)
        if zero1:
            spec = zero1_spec(spec, p.shape, rules)
        return NamedSharding(rules.mesh, spec)

    moment = tree_map(per, descr_tree)
    return {
        "m": moment,
        "v": moment,
        "master": moment,
        "count": NamedSharding(rules.mesh, PartitionSpec()),
    }


def adafactor_state_shardings(descr_tree, rules: ShardingRules,
                              zero1: bool = True):
    def per(p: Param):
        spec = _normalize(rules.spec(p.logical, p.shape), len(p.shape))
        if len(p.shape) >= 2:
            vr_shape, vr_spec = p.shape[:-1], spec[:-1]
            vc_shape = p.shape[:-2] + p.shape[-1:]
            vc_spec = spec[:-2] + spec[-1:]
            vr = PartitionSpec(*vr_spec)
            vc = PartitionSpec(*vc_spec)
            if zero1:
                vr = zero1_spec(vr, vr_shape, rules)
                vc = zero1_spec(vc, vc_shape, rules)
            return {"vr": NamedSharding(rules.mesh, vr),
                    "vc": NamedSharding(rules.mesh, vc)}
        v = PartitionSpec(*spec)
        if zero1:
            v = zero1_spec(v, p.shape, rules)
        return {"v": NamedSharding(rules.mesh, v)}

    return {
        "v": tree_map(per, descr_tree),
        "count": NamedSharding(rules.mesh, PartitionSpec()),
    }


def opt_state_shardings(opt_name: str, descr_tree, rules: ShardingRules,
                        zero1: bool = True):
    if opt_name == "adamw":
        return adamw_state_shardings(descr_tree, rules, zero1)
    if opt_name == "adafactor":
        return adafactor_state_shardings(descr_tree, rules, zero1)
    raise KeyError(opt_name)
