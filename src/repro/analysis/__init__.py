"""expolint — AST-based invariant checker for the ExpoCloud core.

The fault-tolerance guarantees (backup takeover, at-least-once delivery,
trace replay) hold only while a handful of *conventions* hold:

  * ``SchedulerCore`` stays deterministic (no wall clock, no unseeded
    randomness, no environment reads) so snapshot -> restore -> replay is
    byte-identical,
  * every typed effect and protocol message has a handler on the primary,
    backup and client paths,
  * every mutable core field is covered by ``snapshot()``/``restore()``,
  * control broadcasts ride ``ctrl_seq``, never per-client ``srv_seq``
    (the PR-4 divergence bug),
  * Pallas kernels import compiler params through the compat shim and
    check grid divisibility.

``expolint`` turns those conventions into CI-enforced rules:

    PYTHONPATH=src python -m repro.analysis [--root DIR] [--json]

Per-line suppression: append ``# expolint: disable=<rule>`` to the
flagged line; ``# expolint: disable-file=<rule>`` anywhere in a file
suppresses the rule for the whole file.
"""
from __future__ import annotations

from repro.analysis.framework import (Project, Rule, Violation, all_rules,
                                      run_checks)

__all__ = ["Project", "Rule", "Violation", "all_rules", "run_checks"]
