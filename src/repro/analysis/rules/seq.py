"""seq-discipline: broadcasts ride ctrl_seq, never per-client srv_seq.

The PR-4 divergence bug: STOP/RESUME broadcasts consumed one ``srv_seq``
per client on the primary, but the backup (which only sees a single
BROADCAST notice, not per-client FORWARDs) did not mirror that
consumption — after takeover every client's dedup counter disagreed.
The fix gave control broadcasts their own control-plane counter
(``ctrl_seq``).  This rule regression-proofs the discipline:

  1. a ``Send`` effect must never carry *both* ``srv_seq`` and
     ``ctrl_seq`` (one message, one counter plane),
  2. in ``core/scheduler.py``, a send constructed inside an iteration
     over ``self.clients`` in any method **other than** ``on_message``
     is a broadcast and must either pass ``ctrl_seq`` or ride the
     *counterless plane* (ACK / APPLY_DOMINO_EFFECT with neither
     counter: idempotent, order-free deliveries — outbox pops and
     frontier unions — need no dedup counter, so there is no counter
     state to diverge).  It must not use the ``self._send`` helper,
     which consumes ``srv_seq``.  ``on_message`` is exempt: its
     fan-outs replay on the backup through the FORWARDed client
     message, so per-client srv_seq consumption is mirrored exactly,
  3. ``MsgType.STOP``/``MsgType.RESUME`` must never flow through
     ``self._send`` or a ``srv_seq=``-carrying constructor anywhere in
     the core — they are control-plane by definition.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import Project, Rule, Violation

SCHEDULER = "src/repro/core/scheduler.py"
CORE_GLOB = "src/repro/core/*.py"

# methods whose sends replicate via FORWARDed client messages (the backup
# replays the same event, so per-client srv_seq consumption is mirrored)
_REPLICATED_HANDLERS = {"on_message"}
_CONTROL_MEMBERS = {"STOP", "RESUME"}
# message types allowed to fan out with *no* counter at all: their
# deliveries are idempotent and order-free (ACK pops an outbox entry,
# APPLY_DOMINO_EFFECT unions the pruning frontier), so duplicates and
# reorderings are harmless and there is no counter state to diverge
_COUNTERLESS_MEMBERS = {"ACK", "APPLY_DOMINO_EFFECT"}


def _is_clients_iter(node: ast.expr) -> bool:
    """Matches `self.clients`, `self.clients.values()`,
    `self.clients.items()`, `list(self.clients...)`."""
    if isinstance(node, ast.Call):
        func = node.func
        if isinstance(func, ast.Name) and func.id in ("list", "sorted") \
                and node.args:
            return _is_clients_iter(node.args[0])
        if isinstance(func, ast.Attribute) \
                and func.attr in ("values", "items", "keys"):
            return _is_clients_iter(func.value)
        return False
    return (isinstance(node, ast.Attribute) and node.attr == "clients"
            and isinstance(node.value, ast.Name)
            and node.value.id == "self")


def _kw(call: ast.Call, name: str) -> ast.keyword | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw
    return None


def _is_passthrough(call: ast.Call) -> bool:
    """True when srv_seq/ctrl_seq are both forwarded verbatim from the
    same source object (`srv_seq=eff.srv_seq, ctrl_seq=eff.ctrl_seq`) —
    the transport shell copying an effect onto the wire, where exactly
    one field is non-None, not the core allocating both counters."""
    bases = []
    for name in ("srv_seq", "ctrl_seq"):
        kw = _kw(call, name)
        if kw is None or not isinstance(kw.value, ast.Attribute) \
                or kw.value.attr != name \
                or not isinstance(kw.value.value, ast.Name):
            return False
        bases.append(kw.value.value.id)
    return bases[0] == bases[1] and bases[0] != "self"


def _call_name(call: ast.Call) -> str | None:
    func = call.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _is_control_member(node: ast.expr) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "MsgType"
            and node.attr in _CONTROL_MEMBERS)


def _is_counterless_send(call: ast.Call) -> bool:
    """True for ``Send(name, MsgType.ACK/APPLY_DOMINO_EFFECT, ...)``
    carrying *neither* counter kwarg — the counterless plane."""
    if _kw(call, "srv_seq") is not None or _kw(call, "ctrl_seq") is not None:
        return False
    mtypes = [a for a in list(call.args) + [kw.value for kw in call.keywords]
              if isinstance(a, ast.Attribute)
              and isinstance(a.value, ast.Name) and a.value.id == "MsgType"]
    return any(a.attr in _COUNTERLESS_MEMBERS for a in mtypes)


class SeqDisciplineRule(Rule):
    name = "seq-discipline"
    description = ("broadcasts must ride the control-plane ctrl_seq "
                   "counter, never per-client srv_seq")

    def check(self, project: Project) -> list[Violation]:
        out: list[Violation] = []
        for path in project.glob(CORE_GLOB):
            tree = project.tree(path)
            if tree is None:
                continue
            out.extend(self._check_mixed_planes(path, tree))
            out.extend(self._check_control_members(path, tree))
        sched = project.tree(SCHEDULER)
        if sched is not None:
            out.extend(self._check_broadcast_loops(sched))
        return out

    # ------------------------------------------------------------------
    def _check_mixed_planes(self, path: str,
                            tree: ast.AST) -> list[Violation]:
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and _call_name(node) in ("Send", "Message") \
                    and _kw(node, "srv_seq") is not None \
                    and _kw(node, "ctrl_seq") is not None \
                    and not _is_passthrough(node):
                out.append(self.violation(
                    path, node,
                    f"{_call_name(node)}(...) carries both srv_seq and "
                    "ctrl_seq — one message, one counter plane"))
        return out

    def _check_control_members(self, path: str,
                               tree: ast.AST) -> list[Violation]:
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            has_control = any(_is_control_member(a) for a in node.args) \
                or any(_is_control_member(kw.value) for kw in node.keywords)
            if not has_control:
                continue
            if _call_name(node) == "_send":
                out.append(self.violation(
                    path, node,
                    "STOP/RESUME sent through the srv_seq-consuming "
                    "`_send` helper — control broadcasts must go through "
                    "control_broadcast() so the backup's mirror stays in "
                    "agreement"))
            elif _kw(node, "srv_seq") is not None:
                out.append(self.violation(
                    path, node,
                    "STOP/RESUME constructed with srv_seq — control "
                    "broadcasts ride ctrl_seq"))
        return out

    # ------------------------------------------------------------------
    def _check_broadcast_loops(self, tree: ast.AST) -> list[Violation]:
        out: list[Violation] = []
        core = None
        for node in ast.iter_child_nodes(tree):
            if isinstance(node, ast.ClassDef) and node.name == "SchedulerCore":
                core = node
        if core is None:
            return out
        for method in core.body:
            if not isinstance(method, ast.FunctionDef) \
                    or method.name in _REPLICATED_HANDLERS:
                continue
            for loop_body in self._clients_loop_bodies(method):
                for node in loop_body:
                    for call in [n for n in ast.walk(node)
                                 if isinstance(n, ast.Call)]:
                        out.extend(self._check_loop_send(method, call))
        return out

    def _clients_loop_bodies(self, method: ast.FunctionDef) -> list[list]:
        """Bodies of for-loops and comprehension elements iterating over
        self.clients inside ``method``."""
        bodies: list[list] = []
        for node in ast.walk(method):
            if isinstance(node, ast.For) and _is_clients_iter(node.iter):
                bodies.append(node.body)
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.GeneratorExp)):
                if any(_is_clients_iter(gen.iter)
                       for gen in node.generators):
                    bodies.append([node.elt])
        return bodies

    def _check_loop_send(self, method: ast.FunctionDef,
                         call: ast.Call) -> list[Violation]:
        name = _call_name(call)
        if name == "_send":
            return [self.violation(
                SCHEDULER, call,
                f"`self._send` inside a loop over self.clients in "
                f"`{method.name}` — this is a broadcast consuming one "
                "srv_seq per client, which the backup cannot mirror; use "
                "control_broadcast()/ctrl_seq")]
        if name == "Send" and _kw(call, "ctrl_seq") is None \
                and not _is_counterless_send(call):
            return [self.violation(
                SCHEDULER, call,
                f"Send(...) constructed per-client in `{method.name}` "
                "without ctrl_seq — broadcasts must ride the "
                "control-plane counter (or be a counterless "
                "ACK/APPLY_DOMINO_EFFECT carrying no counter at all)")]
        return []
