"""core-purity: the replay-critical core must stay deterministic.

``SchedulerCore`` owes its fault-tolerance guarantees to one property:
the same event stream always produces the same effect stream and the
same ``snapshot()``.  Anything that smuggles ambient state into an event
handler — wall-clock reads, unseeded randomness, environment variables,
thread scheduling — silently breaks byte-identical snapshot -> restore ->
replay, the exact failure mode backup takeover cannot tolerate.

Scope (two tiers):

  * **strict** (``core/scheduler.py``, ``core/hardness.py``,
    ``core/shard.py``): pure state machines — additionally no file I/O,
    ``print`` or console input.
  * **determinism** (``core/trace.py``, ``core/sim.py``): the simulator
    and trace layer may perform explicit, caller-requested persistence
    (``Trace.write``/``load``) but must draw every nondeterministic
    quantity from a *seeded* RNG — ``random.Random(seed)`` is the one
    sanctioned constructor; module-level ``random.*`` calls and
    ``random.Random()`` with no seed are banned alongside the clock,
    environment and threading bans.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import Project, Rule, Violation

STRICT_FILES = (
    "src/repro/core/scheduler.py",
    "src/repro/core/hardness.py",
    "src/repro/core/shard.py",
)
DETERMINISM_FILES = (
    "src/repro/core/trace.py",
    "src/repro/core/sim.py",
)

# module.attr calls that read ambient nondeterministic state
_BANNED_MODULE_CALLS = {
    "time": "wall-clock read (time must arrive as event payload)",
    "datetime": "wall-clock read (datetime must arrive as event payload)",
    "uuid": "nondeterministic identifier (derive names from core counters)",
    "secrets": "nondeterministic randomness",
}
_BANNED_OS_ATTRS = {
    "environ": "environment read (pass config through ServerConfig)",
    "getenv": "environment read (pass config through ServerConfig)",
    "urandom": "nondeterministic randomness",
}
_BANNED_IMPORTS = {
    "threading": "thread scheduling is nondeterministic",
    "multiprocessing": "process scheduling is nondeterministic",
    "asyncio": "event-loop scheduling is nondeterministic",
    "socket": "network I/O in the pure core",
    "subprocess": "process I/O in the pure core",
}
_BANNED_BUILTIN_CALLS = {
    "open": "file I/O in the pure core (persist via the shell)",
    "print": "console I/O in the pure core (use EventLog)",
    "input": "console input in the pure core",
}


def _root_name(node: ast.expr) -> str | None:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


class CorePurityRule(Rule):
    name = "core-purity"
    description = ("replay-critical core files must not read the clock, "
                   "unseeded RNGs, the environment, or perform I/O")

    def check(self, project: Project) -> list[Violation]:
        out: list[Violation] = []
        for path in STRICT_FILES + DETERMINISM_FILES:
            tree = project.tree(path)
            if tree is None:
                continue
            out.extend(self._check_file(path, tree, path in STRICT_FILES))
        return out

    def _check_file(self, path: str, tree: ast.AST,
                    strict: bool) -> list[Violation]:
        out: list[Violation] = []
        call_lines: set[int] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                out.extend(self._check_import(path, node))
            elif isinstance(node, ast.Call):
                found = self._check_call(path, node, strict)
                call_lines.update(v.line for v in found)
                out.extend(found)
        # os.environ reads that are not calls (`os.environ["X"]`, aliasing)
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and node.attr == "environ" \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "os" \
                    and node.lineno not in call_lines:
                out.append(self.violation(
                    path, node,
                    "read of `os.environ`: environment read "
                    "(pass config through ServerConfig)"))
        return out

    def _check_import(self, path: str, node: ast.stmt) -> list[Violation]:
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            names = [node.module]
        else:
            return []
        out = []
        for name in names:
            top = name.split(".")[0]
            if top in _BANNED_IMPORTS:
                out.append(self.violation(
                    path, node,
                    f"import of `{name}`: {_BANNED_IMPORTS[top]}"))
        return out

    def _check_call(self, path: str, node: ast.Call,
                    strict: bool) -> list[Violation]:
        func = node.func
        # builtin I/O calls (strict tier only)
        if strict and isinstance(func, ast.Name) \
                and func.id in _BANNED_BUILTIN_CALLS:
            return [self.violation(
                path, node,
                f"call to `{func.id}(...)`: "
                f"{_BANNED_BUILTIN_CALLS[func.id]}")]
        if not isinstance(func, ast.Attribute):
            return []
        root = _root_name(func)
        if root in _BANNED_MODULE_CALLS:
            return [self.violation(
                path, node,
                f"call to `{root}.{func.attr}(...)`: "
                f"{_BANNED_MODULE_CALLS[root]}")]
        if root == "os" and func.attr in _BANNED_OS_ATTRS:
            return [self.violation(
                path, node,
                f"call to `os.{func.attr}(...)`: "
                f"{_BANNED_OS_ATTRS[func.attr]}")]
        if root == "random":
            # random.Random(seed) is the sanctioned seeded constructor;
            # everything else on the module-level (shared, unseeded) RNG
            # is nondeterministic under replay
            if func.attr == "Random" and (node.args or node.keywords):
                return []
            return [self.violation(
                path, node,
                f"call to `random.{func.attr}(...)`: unseeded/module-level "
                "RNG (use a random.Random(seed) instance)")]
        return []
