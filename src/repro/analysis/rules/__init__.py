"""Rule registry — one module per invariant family."""
from __future__ import annotations

from repro.analysis.rules.exhaustiveness import EffectExhaustivenessRule
from repro.analysis.rules.pallas import PallasRulesRule
from repro.analysis.rules.purity import CorePurityRule
from repro.analysis.rules.seq import SeqDisciplineRule
from repro.analysis.rules.snapshot import SnapshotCompletenessRule

RULES = [
    CorePurityRule,
    EffectExhaustivenessRule,
    SnapshotCompletenessRule,
    SeqDisciplineRule,
    PallasRulesRule,
]

__all__ = ["RULES"]
