"""pallas-rules: kernel hygiene for the TPU Pallas layer.

Two invariants, both born from real breakage:

  1. **compiler-params indirection** — the Pallas TPU compiler-params
     class was renamed upstream (``TPUCompilerParams`` ->
     ``CompilerParams``), which broke every kernel that touched it
     directly (fixed in PR 4).  All access must go through
     ``kernels/pallas_compat.py``, the one module allowed to probe the
     installed API.  This rule flags direct imports or attribute reads
     of ``*CompilerParams`` from ``jax.experimental.pallas.tpu``
     anywhere else under ``src/repro/``.

  2. **grid divisibility** — a ``pallas_call`` grid computed with ``//``
     silently drops the remainder: ``grid=(S // block,)`` with
     ``S % block != 0`` skips the tail elements and produces wrong
     results with no error.  Inside any function that invokes
     ``pl.pallas_call`` — or constructs a ``*GridSpec`` (e.g.
     ``pltpu.PrefetchScalarGridSpec``), which carries a grid to a
     ``pallas_call`` elsewhere — every floor division must be paired
     with a matching ``lhs % rhs`` check (assert or comparison) over the
     same operands in the same function.  Floor divisions inside
     ``lambda`` index maps are exempt — Pallas index maps legitimately
     map block indices with ``//``.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import Project, Rule, Violation

SRC_GLOB = "src/repro/**/*.py"
COMPAT = "src/repro/kernels/pallas_compat.py"

_PALLAS_TPU = "jax.experimental.pallas.tpu"


def _lambda_spans(func: ast.FunctionDef) -> list[tuple[int, int]]:
    spans = []
    for node in ast.walk(func):
        if isinstance(node, ast.Lambda):
            spans.append((node.lineno, node.end_lineno or node.lineno))
    return spans


def _nodes_in_lambdas(func: ast.FunctionDef) -> set[int]:
    """ids of AST nodes nested inside any Lambda in ``func``."""
    inside: set[int] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Lambda):
            for sub in ast.walk(node):
                inside.add(id(sub))
    return inside


def _uses_pallas_call(func: ast.FunctionDef) -> bool:
    """True if ``func`` feeds a Pallas grid: calls ``pallas_call`` itself
    or constructs a ``*GridSpec`` (e.g. ``pltpu.PrefetchScalarGridSpec``)
    that a ``pallas_call`` elsewhere consumes — a grid built with an
    unchecked ``//`` is just as wrong when it reaches the kernel through
    a grid-spec object as through the ``grid=`` kwarg."""
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        callee = node.func
        name = callee.attr if isinstance(callee, ast.Attribute) else (
            callee.id if isinstance(callee, ast.Name) else "")
        if name == "pallas_call" or name.endswith("GridSpec"):
            return True
    return False


class PallasRulesRule(Rule):
    name = "pallas-rules"
    description = ("compiler params only via kernels/pallas_compat.py; "
                   "pallas_call grids built with // need a matching % "
                   "divisibility check")

    def check(self, project: Project) -> list[Violation]:
        out: list[Violation] = []
        for path in project.glob(SRC_GLOB):
            if path == COMPAT:
                continue
            tree = project.tree(path)
            if tree is None:
                continue
            out.extend(self._check_compiler_params(path, tree))
            out.extend(self._check_divisibility(path, tree))
        return out

    # ------------------------------------------------------------------
    # compiler-params access must go through pallas_compat
    # ------------------------------------------------------------------
    def _check_compiler_params(self, path: str,
                               tree: ast.AST) -> list[Violation]:
        out: list[Violation] = []
        tpu_aliases: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom):
                if node.module == _PALLAS_TPU:
                    for alias in node.names:
                        if "CompilerParams" in alias.name:
                            out.append(self.violation(
                                path, node,
                                f"direct import of `{alias.name}` from "
                                f"`{_PALLAS_TPU}` — the upstream name "
                                "drifts; resolve it via "
                                "kernels/pallas_compat.py"))
                elif node.module == "jax.experimental.pallas":
                    for alias in node.names:
                        if alias.name == "tpu":
                            tpu_aliases.add(alias.asname or alias.name)
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == _PALLAS_TPU:
                        tpu_aliases.add(
                            alias.asname or alias.name.split(".")[0])
        if not tpu_aliases:
            return out
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) \
                    and "CompilerParams" in node.attr \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id in tpu_aliases:
                out.append(self.violation(
                    path, node,
                    f"direct access to `{node.value.id}.{node.attr}` — "
                    "the upstream name drifts; resolve it via "
                    "kernels/pallas_compat.py"))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "getattr" and len(node.args) >= 2 \
                    and isinstance(node.args[0], ast.Name) \
                    and node.args[0].id in tpu_aliases \
                    and isinstance(node.args[1], ast.Constant) \
                    and isinstance(node.args[1].value, str) \
                    and "CompilerParams" in node.args[1].value:
                out.append(self.violation(
                    path, node,
                    f"getattr probe for `{node.args[1].value}` outside "
                    "kernels/pallas_compat.py — centralize the API-drift "
                    "probe there"))
        return out

    # ------------------------------------------------------------------
    # floor divisions near pallas_call need % checks
    # ------------------------------------------------------------------
    def _check_divisibility(self, path: str,
                            tree: ast.AST) -> list[Violation]:
        out: list[Violation] = []
        for func in [n for n in ast.walk(tree)
                     if isinstance(n, ast.FunctionDef)]:
            if not _uses_pallas_call(func):
                continue
            in_lambda = _nodes_in_lambdas(func)
            mods: set[tuple[str, str]] = set()
            floordivs: list[ast.BinOp] = []
            for node in ast.walk(func):
                if not isinstance(node, ast.BinOp):
                    continue
                try:
                    operands = (ast.unparse(node.left),
                                ast.unparse(node.right))
                except Exception:
                    continue
                if isinstance(node.op, ast.Mod):
                    mods.add(operands)
                elif isinstance(node.op, ast.FloorDiv) \
                        and id(node) not in in_lambda:
                    floordivs.append(node)
            for node in floordivs:
                operands = (ast.unparse(node.left), ast.unparse(node.right))
                if operands not in mods:
                    out.append(self.violation(
                        path, node,
                        f"`{operands[0]} // {operands[1]}` in "
                        f"pallas_call-using `{func.name}` has no matching "
                        f"`{operands[0]} % {operands[1]}` divisibility "
                        "check — a non-dividing shape silently drops the "
                        "tail block"))
        return out
