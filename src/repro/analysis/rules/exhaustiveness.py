"""effect-exhaustiveness: no half-wired effects, events or messages.

Three checks, all rooted in how a new protocol arm actually ships:

  1. every **effect** dataclass declared in ``core/scheduler.py`` (the
     classes under the ``typed effects (outputs)`` banner) must have an
     ``isinstance`` branch in ``Server._apply`` — the single dispatch
     point both the primary and the backup execute effects through; an
     unhandled effect is silently dropped at runtime,
  2. every **event** dataclass (under the ``typed events (inputs)``
     banner) must have an ``isinstance`` branch in
     ``SchedulerCore.handle`` — the replay entry point; an unhandled
     event kills takeover replay with a TypeError,
  3. every ``MsgType`` member must be both **produced** (passed to a
     call: ``Message(MsgType.X, ...)``, ``self._send(ci, MsgType.X)``,
     ``send_to_servers(MsgType.X)``, ...) and **consumed** (compared
     against ``msg.type`` or listed in a dispatch container such as
     ``_REPLICATED``/``_NEEDS_ACK``) somewhere across the core — a
     member with producers but no consumer is a message the protocol
     sends into the void; a member with consumers but no producer is a
     dead protocol arm.  References to undefined members
     (``MsgType.TYPO``) are flagged too.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import Project, Rule, Violation

SCHEDULER = "src/repro/core/scheduler.py"
SERVER = "src/repro/core/server.py"
MESSAGES = "src/repro/core/messages.py"
CORE_GLOB = "src/repro/core/*.py"

_EVENTS_BANNER = "typed events (inputs)"
_EFFECTS_BANNER = "typed effects (outputs)"


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else \
            getattr(target, "id", None)
        if name == "dataclass":
            return True
    return False


def _banner_sections(project: Project, path: str) -> tuple[int, int, int]:
    """(events_start, effects_start, end) line numbers; -1 when a banner
    is missing."""
    events = effects = -1
    for i, ln in enumerate(project.lines(path), 1):
        if _EVENTS_BANNER in ln and events < 0:
            events = i
        elif _EFFECTS_BANNER in ln and effects < 0:
            effects = i
    return events, effects, len(project.lines(path)) + 1


def _section_dataclasses(tree: ast.AST, start: int,
                         stop: int) -> list[ast.ClassDef]:
    return [n for n in ast.iter_child_nodes(tree)
            if isinstance(n, ast.ClassDef) and _is_dataclass(n)
            and start < n.lineno < stop]


def _find_class(tree: ast.AST, name: str) -> ast.ClassDef | None:
    for node in ast.iter_child_nodes(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _find_method(cls: ast.ClassDef, name: str) -> ast.FunctionDef | None:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _isinstance_targets(func: ast.FunctionDef) -> set[str]:
    """Class names appearing as the second argument of isinstance calls
    (single name or tuple of names)."""
    out: set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "isinstance" and len(node.args) == 2:
            spec = node.args[1]
            elts = spec.elts if isinstance(spec, ast.Tuple) else [spec]
            for e in elts:
                if isinstance(e, ast.Name):
                    out.add(e.id)
                elif isinstance(e, ast.Attribute):
                    out.add(e.attr)
    return out


class EffectExhaustivenessRule(Rule):
    name = "effect-exhaustiveness"
    description = ("every effect/event dataclass and every MsgType member "
                   "must be fully wired (emitted AND handled)")

    def check(self, project: Project) -> list[Violation]:
        out: list[Violation] = []
        out.extend(self._check_effects_and_events(project))
        out.extend(self._check_msgtypes(project))
        return out

    # ------------------------------------------------------------------
    # effects -> Server._apply; events -> SchedulerCore.handle
    # ------------------------------------------------------------------
    def _check_effects_and_events(self,
                                  project: Project) -> list[Violation]:
        tree = project.tree(SCHEDULER)
        if tree is None:
            return []
        events_at, effects_at, eof = _banner_sections(project, SCHEDULER)
        core = _find_class(tree, "SchedulerCore")
        out: list[Violation] = []
        if effects_at < 0 or events_at < 0 or core is None:
            out.append(self.violation(
                SCHEDULER, 1,
                "scheduler.py must keep the `typed events (inputs)` / "
                "`typed effects (outputs)` banners and the SchedulerCore "
                "class — expolint classifies the protocol dataclasses "
                "by them"))
            return out
        stop = min(x for x in (core.lineno, eof))
        events = _section_dataclasses(tree, events_at, effects_at)
        effects = _section_dataclasses(tree, effects_at, stop)

        handled_events = _isinstance_targets(_find_method(core, "handle")) \
            if _find_method(core, "handle") else set()
        for cls in events:
            if cls.name not in handled_events:
                out.append(self.violation(
                    SCHEDULER, cls,
                    f"event `{cls.name}` has no isinstance branch in "
                    "SchedulerCore.handle — takeover replay would raise "
                    "TypeError on it"))

        server_tree = project.tree(SERVER)
        handled_effects: set[str] = set()
        if server_tree is not None:
            server_cls = _find_class(server_tree, "Server")
            apply_fn = _find_method(server_cls, "_apply") \
                if server_cls else None
            if apply_fn is not None:
                handled_effects = _isinstance_targets(apply_fn)
        for cls in effects:
            if cls.name not in handled_effects:
                out.append(self.violation(
                    SCHEDULER, cls,
                    f"effect `{cls.name}` has no isinstance branch in "
                    "Server._apply — the shell would silently drop it on "
                    "both the primary and backup paths"))
        return out

    # ------------------------------------------------------------------
    # MsgType members: produced AND consumed
    # ------------------------------------------------------------------
    def _msgtype_members(self, project: Project) -> set[str] | None:
        tree = project.tree(MESSAGES)
        if tree is None:
            return None
        enum_cls = _find_class(tree, "MsgType")
        if enum_cls is None:
            return None
        members: set[str] = set()
        for node in enum_cls.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        members.add(tgt.id)
        return members

    def _check_msgtypes(self, project: Project) -> list[Violation]:
        members = self._msgtype_members(project)
        if members is None:
            return []
        produced: dict[str, tuple[str, int]] = {}
        consumed: dict[str, tuple[str, int]] = {}
        out: list[Violation] = []
        for path in project.glob(CORE_GLOB):
            tree = project.tree(path)
            if tree is None or path == MESSAGES:
                continue
            refs = self._classify_refs(tree)
            for member, line, kind in refs:
                if member not in members:
                    out.append(self.violation(
                        path, line,
                        f"reference to undefined member MsgType.{member}"))
                    continue
                bucket = produced if kind == "produced" else consumed
                bucket.setdefault(member, (path, line))
        for member in sorted(produced.keys() - consumed.keys()):
            path, line = produced[member]
            out.append(self.violation(
                path, line,
                f"MsgType.{member} is constructed here but consumed "
                f"nowhere (no `== MsgType.{member}` comparison or "
                "dispatch-container entry on the primary/backup/client "
                "loops)"))
        for member in sorted(consumed.keys() - produced.keys()):
            path, line = consumed[member]
            out.append(self.violation(
                path, line,
                f"MsgType.{member} is consumed here but constructed "
                "nowhere — dead protocol arm"))
        return out

    def _classify_refs(self, tree: ast.AST) -> list[tuple[str, int, str]]:
        """(member, line, 'produced'|'consumed') for every MsgType.X whose
        syntactic role is recognizable.  Call arguments are producers
        (message construction/send helpers); comparison operands and
        container-literal elements are consumers (dispatch)."""
        refs: list[tuple[str, int, str]] = []

        def is_msgtype_ref(node: ast.expr) -> str | None:
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "MsgType":
                return node.attr
            return None

        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                for arg in node.args:
                    member = is_msgtype_ref(arg)
                    if member is not None:
                        refs.append((member, arg.lineno, "produced"))
                for kw in node.keywords:
                    member = is_msgtype_ref(kw.value)
                    if member is not None:
                        refs.append((member, kw.value.lineno, "produced"))
            elif isinstance(node, ast.Compare):
                for operand in [node.left, *node.comparators]:
                    member = is_msgtype_ref(operand)
                    if member is not None:
                        refs.append((member, operand.lineno, "consumed"))
            elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
                for elt in node.elts:
                    member = is_msgtype_ref(elt)
                    if member is not None:
                        refs.append((member, elt.lineno, "consumed"))
            elif isinstance(node, ast.Dict):
                for key in node.keys:
                    if key is None:
                        continue
                    member = is_msgtype_ref(key)
                    if member is not None:
                        refs.append((member, key.lineno, "consumed"))
        return refs
