"""snapshot-completeness: every mutable core field survives takeover.

Backup takeover restores ``SchedulerCore`` from ``snapshot()`` and
replays the forwarded stream into it.  A field assigned in ``__init__``
but missing from ``snapshot()``/``restore()`` silently resets on the
backup — the exact divergence class behind the srv_seq bug (PR 4): both
sides keep running, their states drift, and the first takeover
double-assigns or loses work.

The rule cross-references three sites per snapshot-bearing core class
(``SchedulerCore`` in ``core/scheduler.py``, ``ShardCoordinator`` in
``core/shard.py``):

  * attributes assigned on ``self`` directly in the class ``__init__``
    (derived state built by helpers like ``_build_policies`` /
    ``_init_derived`` is excluded because it is deterministically
    rebuilt on both paths),
  * string keys of the dict literal returned by ``snapshot()``,
  * attributes assigned in ``restore()``.

A leading-underscore attribute matches a key with the underscore
stripped (``_task_started`` <-> ``"task_started"``).  Both directions are
checked: an ``__init__`` field missing from either site, and a snapshot
key with no backing field (stale after a refactor).
"""
from __future__ import annotations

import ast

from repro.analysis.framework import Project, Rule, Violation

SCHEDULER = "src/repro/core/scheduler.py"

# (path, class) pairs whose snapshot()/restore() must round-trip every
# __init__ field — takeover (SchedulerCore) and sharded-run resume
# (ShardCoordinator) both silently drop state otherwise
TARGETS = (
    (SCHEDULER, "SchedulerCore"),
    ("src/repro/core/shard.py", "ShardCoordinator"),
)


def _find_class(tree: ast.AST, name: str) -> ast.ClassDef | None:
    for node in ast.iter_child_nodes(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _find_method(cls: ast.ClassDef, name: str) -> ast.FunctionDef | None:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _self_assigns(func: ast.FunctionDef) -> dict[str, int]:
    """attr -> first assignment line for `self.attr = ...` (plain,
    annotated and augmented assignments)."""
    out: dict[str, int] = {}
    for node in ast.walk(func):
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
        for tgt in targets:
            if isinstance(tgt, ast.Attribute) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id == "self":
                out.setdefault(tgt.attr, tgt.lineno)
    return out


def _restore_assigns(func: ast.FunctionDef) -> set[str]:
    """Attributes assigned on any local object in restore()
    (``core.attr = ...``)."""
    out: set[str] = set()
    for node in ast.walk(func):
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
        for tgt in targets:
            if isinstance(tgt, ast.Attribute) \
                    and isinstance(tgt.value, ast.Name):
                out.add(tgt.attr)
    return out


def _snapshot_keys(func: ast.FunctionDef) -> dict[str, int] | None:
    """Constant string keys of the dict literal snapshot() returns."""
    for node in ast.walk(func):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Dict):
            out: dict[str, int] = {}
            for key in node.value.keys:
                if isinstance(key, ast.Constant) and isinstance(key.value,
                                                                str):
                    out[key.value] = key.lineno
            return out
    return None


class SnapshotCompletenessRule(Rule):
    name = "snapshot-completeness"
    description = ("every snapshot-bearing core class's __init__ field "
                   "must appear in snapshot() and be reassigned in "
                   "restore()")

    def check(self, project: Project) -> list[Violation]:
        out: list[Violation] = []
        for path, cls_name in TARGETS:
            tree = project.tree(path)
            if tree is None:
                continue
            cls = _find_class(tree, cls_name)
            if cls is None:
                continue
            out.extend(self._check_class(path, cls_name, cls))
        return out

    def _check_class(self, path: str, cls_name: str,
                     cls: ast.ClassDef) -> list[Violation]:
        init = _find_method(cls, "__init__")
        snapshot = _find_method(cls, "snapshot")
        restore = _find_method(cls, "restore")
        out: list[Violation] = []
        if init is None or snapshot is None or restore is None:
            out.append(self.violation(
                path, cls,
                f"{cls_name} must define __init__, snapshot() and "
                "restore() — takeover/resume depends on all three"))
            return out
        keys = _snapshot_keys(snapshot)
        if keys is None:
            out.append(self.violation(
                path, snapshot,
                "snapshot() must return a dict literal with constant "
                "string keys so completeness is statically checkable"))
            return out
        fields = _self_assigns(init)
        restored = _restore_assigns(restore)
        # fields that __init__ builds via helper calls rather than direct
        # self-assignments are invisible here by design (_build_policies /
        # _init_derived rebuild derived objects from config on both paths)
        for attr, line in sorted(fields.items()):
            key = attr.lstrip("_")
            if attr not in keys and key not in keys:
                out.append(self.violation(
                    path, line,
                    f"core field `self.{attr}` is not captured by "
                    "snapshot() — it silently resets on backup "
                    "restore/takeover"))
            if attr not in restored:
                out.append(self.violation(
                    path, line,
                    f"core field `self.{attr}` is not reassigned in "
                    "restore() — restored cores would lack it"))
        field_keys = {a.lstrip("_") for a in fields} | set(fields)
        for key, line in sorted(keys.items()):
            if key not in field_keys:
                out.append(self.violation(
                    path, line,
                    f"snapshot() key \"{key}\" has no matching "
                    f"{cls_name}.__init__ field — stale after a "
                    "refactor?"))
        return out
