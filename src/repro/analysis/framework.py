"""Rule framework for expolint (see package docstring).

A ``Rule`` inspects a ``Project`` (lazy AST/source cache rooted at the
repo) and returns ``Violation``s.  The runner applies suppression
comments afterwards, so rules never need to know about them:

  * ``# expolint: disable=rule-a,rule-b`` on the flagged line,
  * ``# expolint: disable-file=rule-a`` anywhere in the file.

Rules address files by repo-relative POSIX paths and must tolerate
missing files (a fixture mini-project provides only the files its case
needs; so does a future repo layout change — absent file, no findings).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path

_SUPPRESS_LINE = re.compile(r"#\s*expolint:\s*disable=([\w,\- ]+)")
_SUPPRESS_FILE = re.compile(r"#\s*expolint:\s*disable-file=([\w,\- ]+)")


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str          # repo-relative POSIX path
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


class Project:
    """Lazy source/AST cache over a project root."""

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self._sources: dict[str, str | None] = {}
        self._trees: dict[str, ast.AST | None] = {}

    def exists(self, relpath: str) -> bool:
        return (self.root / relpath).is_file()

    def source(self, relpath: str) -> str | None:
        if relpath not in self._sources:
            p = self.root / relpath
            self._sources[relpath] = (
                p.read_text(encoding="utf-8") if p.is_file() else None)
        return self._sources[relpath]

    def lines(self, relpath: str) -> list[str]:
        src = self.source(relpath)
        return src.splitlines() if src is not None else []

    def tree(self, relpath: str) -> ast.AST | None:
        """Parsed AST, or None when the file is missing or unparsable
        (a syntax error is ruff/py_compile's job, not expolint's)."""
        if relpath not in self._trees:
            src = self.source(relpath)
            try:
                self._trees[relpath] = (
                    None if src is None else ast.parse(src))
            except SyntaxError:
                self._trees[relpath] = None
        return self._trees[relpath]

    def glob(self, pattern: str) -> list[str]:
        """Repo-relative POSIX paths matching ``pattern``, sorted."""
        return sorted(
            p.relative_to(self.root).as_posix()
            for p in self.root.glob(pattern) if p.is_file())


class Rule:
    """Base class: subclasses set ``name``/``description`` and implement
    ``check``."""

    name = "abstract"
    description = ""

    def check(self, project: Project) -> list[Violation]:
        raise NotImplementedError

    def violation(self, path: str, node_or_line, message: str) -> Violation:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Violation(self.name, path, int(line), message)


def _suppressed(project: Project, v: Violation) -> bool:
    lines = project.lines(v.path)
    for ln in lines:
        m = _SUPPRESS_FILE.search(ln)
        if m and v.rule in [s.strip() for s in m.group(1).split(",")]:
            return True
    if 1 <= v.line <= len(lines):
        m = _SUPPRESS_LINE.search(lines[v.line - 1])
        if m and v.rule in [s.strip() for s in m.group(1).split(",")]:
            return True
    return False


def all_rules() -> list[Rule]:
    from repro.analysis.rules import RULES

    return [cls() for cls in RULES]


def run_checks(root: str | Path, rules: list[str] | None = None,
               ) -> list[Violation]:
    """Run (a subset of) the rules against ``root``; suppression comments
    already applied.  Unknown rule names raise ValueError."""
    project = Project(root)
    selected = all_rules()
    if rules is not None:
        by_name = {r.name: r for r in selected}
        unknown = [n for n in rules if n not in by_name]
        if unknown:
            known = ", ".join(sorted(by_name))
            raise ValueError(
                f"unknown rule(s) {unknown}; known rules: {known}")
        selected = [by_name[n] for n in rules]
    out: list[Violation] = []
    for rule in selected:
        for v in rule.check(project):
            if not _suppressed(project, v):
                out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out
