"""CLI: ``python -m repro.analysis [--root DIR] [--json] [--rules ...]``.

Exit codes: 0 clean, 1 violations found, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.framework import all_rules, run_checks


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="expolint: AST-based invariant checks for the "
                    "ExpoCloud core, protocol and Pallas kernels.")
    parser.add_argument("--root", default=".",
                        help="repository root to check (default: cwd)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit machine-readable JSON")
    parser.add_argument("--rules", default=None,
                        help="comma-separated subset of rule names")
    parser.add_argument("--list-rules", action="store_true",
                        help="list available rules and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name}: {rule.description}")
        return 0

    names = None
    if args.rules is not None:
        names = [n.strip() for n in args.rules.split(",") if n.strip()]
    try:
        violations = run_checks(args.root, rules=names)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.as_json:
        payload = {
            "root": args.root,
            "rules": names or [r.name for r in all_rules()],
            "violations": [v.to_dict() for v in violations],
            "ok": not violations,
        }
        print(json.dumps(payload, indent=2))
    else:
        for v in violations:
            print(v.format())
        n = len(violations)
        print(f"expolint: {n} violation{'s' if n != 1 else ''} found"
              if n else "expolint: clean")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
