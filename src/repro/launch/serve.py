"""Serving launcher: continuous-batching decode over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --requests 8 --slots 4 --max-new 16 \
        --mode fused --steps-per-sync 8 --prefill-chunk 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--preset", choices=["reduced", "full"],
                    default="reduced")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=["fused", "host"], default="fused",
                    help="fused: N decode steps per host sync; "
                         "host: seed-style sync every step")
    ap.add_argument("--steps-per-sync", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="batched prefill chunk size (0 = sequential "
                         "one-token-per-step prompt forcing)")
    ap.add_argument("--max-prefill-tokens-per-sync", type=int, default=None,
                    help="admission budget on prefill work per sync")
    ap.add_argument("--kv-layout", choices=["dense", "paged"],
                    default="dense",
                    help="dense: per-slot max_seq KV stripes; paged: "
                         "shared page pool with memory-aware admission")
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV rows per page (paged layout; default: the "
                         "repro.tune best-config cache, else 16)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size in pages (paged layout; default "
                         "slots * ceil(max_seq/page_size))")
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced_config
    from repro.models import lm
    from repro.models.params import init_params
    from repro.serve.engine import DecodeEngine, Request

    cfg = (reduced_config(args.arch) if args.preset == "reduced"
           else get_config(args.arch))
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(args.seed))
    eng = DecodeEngine(
        cfg, params, batch_slots=args.slots, max_seq=args.max_seq,
        rng_seed=args.seed, mode=args.mode,
        steps_per_sync=args.steps_per_sync,
        prefill_chunk=args.prefill_chunk,
        max_prefill_tokens_per_sync=args.max_prefill_tokens_per_sync,
        kv_layout=args.kv_layout, page_size=args.page_size,
        num_pages=args.num_pages)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.integers(2, 9))
        shape = (plen, cfg.num_codebooks) if cfg.num_codebooks else plen
        prompt = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new,
                            temperature=args.temperature,
                            top_k=args.top_k))
        eng.submit(reqs[-1])
    t0 = time.time()
    steps = eng.run_until_drained()
    dt = time.time() - t0
    total = sum(len(r.output) for r in reqs)
    print(f"[launch.serve] {args.arch}: {args.requests} requests, "
          f"{total} tokens in {steps} steps / {dt:.1f}s "
          f"({total/dt:.1f} tok/s, {args.slots} slots, {args.mode} mode)")
    if args.kv_layout == "paged":
        ks = eng.kv_stats()
        print(f"[launch.serve] paged KV: {ks['num_pages']} pages x "
              f"{ks['page_size']} rows, high water {ks['high_water']}, "
              f"{ks['preemptions']} preemptions, "
              f"{ks['rejected']} rejected")


if __name__ == "__main__":
    main()
