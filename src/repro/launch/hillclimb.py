"""§Perf hillclimbing driver: run the unrolled probe set for one cell under
a variant, extrapolate to the full config, and print the roofline row —
the measure step of the hypothesis -> change -> measure -> validate loop.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch deepseek-v3-671b \
        --shape train_4k --variant moe=ep --out dryrun_results_perf
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config, get_shape
from repro.configs.analysis import model_flops
from repro.configs.registry import segment_counts
from repro.core.sweep import DryRunCellTask, probe_plans
from repro.launch.aggregate import METRICS, extrapolate_linear
from repro.launch.roofline import Roofline


def run_variant(arch: str, shape: str, variant: dict, out_dir: str,
                deadline: float = 1800.0, devices: int = 512) -> dict:
    cfg = get_config(arch)
    plans = probe_plans(arch)
    recs = []
    for plan in plans:
        task = DryRunCellTask(arch, shape, "single", plan,
                              dict(variant, unroll=1), deadline, out_dir,
                              devices=devices)
        res = task.run()
        assert res[0] == "ok", res
        with open(res[-1]) as f:
            recs.append(json.load(f))
    base, bumped = recs[0], recs[1:]
    base_m = {m: base["roofline"][m] for m in METRICS}
    bump_m = [{m: b["roofline"][m] for m in METRICS} for b in bumped]
    full_counts = tuple(segment_counts(cfg))
    base_counts = tuple(plans[0])
    full_m = extrapolate_linear(base_m, bump_m, base_counts, full_counts)
    mf = model_flops(cfg, get_shape(shape))
    r = Roofline(
        arch=arch, shape=shape, mesh="data16xmodel16",
        chips=base["roofline"]["chips"],
        hlo_flops=max(full_m["hlo_flops"], 0.0),
        hlo_bytes=max(full_m["hlo_bytes"], 0.0),
        collective_bytes_per_chip=max(full_m["collective_bytes_per_chip"],
                                      0.0),
        collectives={}, collective_counts={}, model_flops=mf,
    ).finalize()
    return {
        "arch": arch, "shape": shape, "variant": variant,
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "dominant": r.dominant,
        "useful_ratio": r.useful_ratio,
        "roofline_fraction": r.roofline_fraction,
        "probe_compile_s": [x["compile_s"] for x in recs],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", nargs="*", default=[])
    ap.add_argument("--out", default="dryrun_results_perf")
    ap.add_argument("--devices", type=int, default=512)
    args = ap.parse_args(argv)
    variant = {}
    for kv in args.variant:
        k, v = kv.split("=", 1)
        variant[k] = int(v) if v.isdigit() else v
    row = run_variant(args.arch, args.shape, variant, args.out,
                      devices=args.devices)
    print(json.dumps(row, indent=1, default=float))


if __name__ == "__main__":
    main()
