"""Aggregate dry-run sweep records into the §Roofline table.

Why extrapolation: ``cost_analysis()`` counts a ``while`` (lax.scan) body
once, so full-config scanned compiles under-report FLOPs/bytes by ~L x.
The probes compile UNROLLED modules at small segment counts (base and
base+1 per segment); every per-chip metric is affine in the segment counts
(layers are homogeneous within a segment), so

    m(counts) = intercept + sum_i slope_i * counts_i

is exact, and evaluating at the full counts reconstructs the true whole-step
metric.  The full-config compile still provides the lower/compile *proof*
and the sharding-derived bytes/device.

    PYTHONPATH=src python -m repro.launch.aggregate --dir dryrun_results \
        --markdown EXPERIMENTS_roofline.md
"""
from __future__ import annotations

import argparse
import contextlib
import glob
import json
import os
from collections import defaultdict

from repro.configs import cells, get_config, get_shape
from repro.configs.analysis import model_flops, param_counts
from repro.configs.registry import segment_counts
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline

METRICS = ("hlo_flops", "hlo_bytes", "collective_bytes_per_chip")


def extrapolate_linear(base: dict, bumped: list[dict], base_counts: tuple,
                       full_counts: tuple) -> dict:
    """base measured at base_counts; bumped[i] at base_counts + e_i."""
    out = {}
    for m in base:
        if not isinstance(base[m], (int, float)):
            continue
        slopes = [b[m] - base[m] for b in bumped]
        val = base[m]
        for s, c0, cf in zip(slopes, base_counts, full_counts,
                             strict=False):
            val += s * (cf - c0)
        out[m] = val
    return out


def load_records(directory: str) -> dict:
    recs = {}
    for path in glob.glob(os.path.join(directory, "*.json")):
        with open(path) as f, \
                contextlib.suppress(json.JSONDecodeError):
            recs[os.path.basename(path)] = json.load(f)
    return recs


def probe_key(arch, shape, counts):
    return f"{arch}__{shape}__single__L{'-'.join(map(str, counts))}_unroll-1.json"


def full_key(arch, shape, mesh):
    return f"{arch}__{shape}__{mesh}__full.json"


def assemble(directory: str, mesh: str = "single"):
    """Returns list of row dicts (one per runnable cell)."""
    recs = load_records(directory)
    rows = []
    for arch, shape_name in cells():
        cfg = get_config(arch)
        shape = get_shape(shape_name)
        full_counts = tuple(segment_counts(cfg))
        if cfg.hybrid_block:
            base_counts = (1,)
        elif len(full_counts) == 2:
            base_counts = (1, 2)
        else:
            base_counts = (2,)
        bump_keys = []
        for i in range(len(base_counts)):
            b = list(base_counts)
            b[i] += 1
            bump_keys.append(probe_key(arch, shape_name, b))
        base_rec = recs.get(probe_key(arch, shape_name, base_counts))
        bump_recs = [recs.get(k) for k in bump_keys]
        full_rec = recs.get(full_key(arch, shape_name, mesh))
        row = {"arch": arch, "shape": shape_name, "mesh": mesh,
               "status": "missing"}
        if full_rec is not None and full_rec.get("status") == "ok":
            row["status"] = "ok"
            row["compile_s"] = full_rec["compile_s"]
            row["bytes_per_device"] = full_rec["bytes_per_device_inputs"]
            row["memory_analysis"] = full_rec["memory_analysis"][:200]
        if base_rec and all(bump_recs) \
                and base_rec.get("status") == "ok" \
                and all(b.get("status") == "ok" for b in bump_recs):
            chips = base_rec["roofline"]["chips"]
            base_m = {m: base_rec["roofline"][m] for m in METRICS}
            bump_m = [{m: b["roofline"][m] for m in METRICS}
                      for b in bump_recs]
            full_m = extrapolate_linear(base_m, bump_m, base_counts,
                                        full_counts)
            mf = model_flops(cfg, shape)
            r = Roofline(
                arch=arch, shape=shape_name, mesh=mesh, chips=chips,
                hlo_flops=max(full_m["hlo_flops"], 0.0),
                hlo_bytes=max(full_m["hlo_bytes"], 0.0),
                collective_bytes_per_chip=max(
                    full_m["collective_bytes_per_chip"], 0.0),
                collectives={}, collective_counts={},
                model_flops=mf,
            ).finalize()
            row.update(
                compute_s=r.compute_s, memory_s=r.memory_s,
                collective_s=r.collective_s, dominant=r.dominant,
                useful_ratio=r.useful_ratio,
                roofline_fraction=r.roofline_fraction,
                model_flops=mf,
                hlo_flops=r.hlo_flops,
                status_roofline="extrapolated",
            )
        rows.append(row)
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | chips | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | inputs GB/dev |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if "compute_s" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | 256 "
                f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                f"| {r['collective_s']:.3f} | **{r['dominant']}** "
                f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
                f"| {r.get('bytes_per_device', 0)/1e9:.2f} |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | 256 "
                         f"| - | - | - | {r['status']} | - | - | - |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun_results")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows = assemble(args.dir, args.mesh)
    md = to_markdown(rows)
    print(md)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(md + "\n")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1, default=float)


if __name__ == "__main__":
    main()
