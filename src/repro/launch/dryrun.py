import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
# NOTE: the two lines above MUST run before any other import (including
# jax and repro.*): jax locks the device count on first backend init.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--json out.json] [--variant k=v ...]

Succeeding here proves the distribution config is coherent: shardings
resolve, collectives lower, and the memory analysis is reported per cell.
Exercised for the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh.

Variants (perf hillclimbing knobs; defaults = paper-faithful baseline):
    remat=dots|none|full   activation checkpointing policy
    seq_shard=0|1          shard sequence dim over 'data' (SP)
    zero1=0|1              ZeRO-1 optimizer-state sharding
    optimizer=adamw|adafactor
    donate=0|1             donate params/opt buffers
    flash_block_q / flash_block_k (informational on CPU)
"""
import argparse
import contextlib
import json
import math
import sys
import time


def parse_variant(pairs):
    out = {"remat": "dots", "seq_shard": 0, "zero1": 1,
           "optimizer": "adamw", "donate": 1}
    for p in pairs or []:
        k, v = p.split("=", 1)
        out[k] = int(v) if v.isdigit() else v
    return out


def tree_local_bytes(tree) -> float:
    """Per-device bytes of a ShapeDtypeStruct tree honouring shardings."""
    import jax
    import numpy as np

    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        nbytes = math.prod(leaf.shape) * leaf.dtype.itemsize \
            if leaf.shape else leaf.dtype.itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None:
            with contextlib.suppress(Exception):
                local = sh.shard_shape(leaf.shape)
                nbytes = math.prod(local) * leaf.dtype.itemsize
        total += nbytes
    return float(total)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             variant: dict | None = None, mesh_shape=None, mesh_axes=None,
             seg_counts=None, verbose: bool = True) -> dict:
    import jax
    from repro.configs import get_config, get_shape, shape_applicable
    from repro.configs.analysis import model_flops, param_counts
    from repro.launch import roofline as R
    from repro.launch.inputs import input_specs
    from repro.launch.mesh import make_mesh, make_production_mesh
    from repro.models import lm
    from repro.sharding.rules import make_rules, use_rules
    from repro.train.optimizer import get_optimizer
    from repro.train.schedule import warmup_cosine
    from repro.train.train_step import make_train_step

    from repro.configs.registry import with_segment_counts

    variant = dict(variant or {})
    v = parse_variant([])
    v.update(variant)
    cfg = get_config(arch)
    if seg_counts is not None:
        cfg = with_segment_counts(cfg, list(seg_counts))
    unroll = bool(v.get("unroll", 0))
    if unroll:
        os.environ["REPRO_UNROLL_INNER"] = "1"
        os.environ.setdefault("REPRO_SSD_CHUNK", "512")
    if v.get("moe"):                      # MoE dispatch strategy (§Perf)
        os.environ["REPRO_MOE"] = str(v["moe"])
    if v.get("flash_block"):              # KV block size of the flash path
        os.environ["REPRO_FLASH_BLOCK"] = str(v["flash_block"])
    if v.get("moe_cf"):                   # MoE capacity factor override
        os.environ["REPRO_MOE_CF"] = str(v["moe_cf"])
    if v.get("xent_chunk"):               # loss chunk length
        os.environ["REPRO_XENT_CHUNK"] = str(v["xent_chunk"])
    shape = get_shape(shape_name)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "inapplicable",
                "note": "full-attention arch at 500k (by design; DESIGN.md)"}

    t0 = time.time()
    mesh = (make_mesh(tuple(mesh_shape), tuple(mesh_axes))
            if mesh_shape is not None
            else make_production_mesh(multi_pod=multi_pod))
    chips = math.prod(mesh.shape.values())
    mesh_desc = "x".join(f"{k}{v_}" for k, v_ in mesh.shape.items())
    rules = make_rules(mesh, seq_shard=bool(v["seq_shard"]))
    if v.get("kv_shard_model"):
        # decode-cell fix: shard the KV/latent cache's sequence dim over the
        # (otherwise idle at decode) TP axis -> cache bytes/device /16 and
        # attention reads become a psum over 'model'
        rules.table.update(seq_kv=("model",))
    if v.get("sp_model"):
        # Megatron-style sequence parallelism: residual/norm activations
        # sharded over the TP axis on the sequence dim -> XLA turns the
        # per-layer all-reduces into reduce-scatter + all-gather pairs
        rules.table.update(seq=("model",))
    if v.get("dp_only"):
        # §Perf sharding-scheme variant: fold the 'model' axis into data
        # parallelism (no TP) — right-sizes tiny models on the fixed mesh
        rules.table.update(
            batch=tuple(mesh.axis_names),
            heads=(), kv_heads=(), ffn=(), vocab=(), experts=(),
        )

    opt_name = v["optimizer"]
    opt = get_optimizer(opt_name)
    remat = v["remat"] != "none"

    with mesh, use_rules(rules):
        if shape.kind == "train":
            lr_fn = warmup_cosine(3e-4, 100, 10_000)
            step_fn = make_train_step(cfg, opt, lr_fn, remat=remat,
                                      unroll=unroll)
            args = input_specs(cfg, shape, rules, opt=opt, opt_name=opt_name,
                               zero1=bool(v["zero1"]))
            donate = (0, 1) if v["donate"] else ()
            jitted = jax.jit(step_fn, donate_argnums=donate)
        elif shape.kind == "prefill":
            args = input_specs(cfg, shape, rules)
            jitted = jax.jit(
                lambda p, b: lm.prefill(cfg, p, b, unroll=unroll))
        else:
            args = input_specs(cfg, shape, rules)
            donate = (2,) if v["donate"] else ()
            jitted = jax.jit(
                lambda p, b, c: lm.decode_step(cfg, p, b, c, unroll=unroll),
                donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem_note = ""
    try:
        mem = compiled.memory_analysis()
        mem_note = str(mem)
    except Exception as e:  # CPU backend may not support it
        mem_note = f"memory_analysis unavailable on this backend: {e}"
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception:
        cost = {}
    hlo = compiled.as_text()

    bytes_per_device = tree_local_bytes(args)
    mf = model_flops(cfg, shape)
    roof = R.analyze(arch=arch, shape=shape_name, mesh_desc=mesh_desc,
                     chips=chips, cost=cost, hlo_text=hlo, model_flops=mf,
                     bytes_per_device=bytes_per_device)
    pc = param_counts(cfg)
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_desc,
        "status": "ok", "chips": chips,
        "variant": v, "seg_counts": seg_counts,
        "num_layers": cfg.num_layers,
        "params_total": pc.total, "params_active": pc.active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_note,
        "bytes_per_device_inputs": bytes_per_device,
        "roofline": json.loads(roof.to_json()),
        "hlo_bytes_len": len(hlo),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {mesh_desc}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"inputs {bytes_per_device/1e9:.2f} GB/device | "
              f"dominant={roof.dominant} "
              f"compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"useful={roof.useful_ratio:.2f} "
              f"roofline_frac={roof.roofline_fraction:.3f}")
        print(f"[dryrun] memory_analysis: {mem_note[:400]}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh-shape", type=int, nargs="*", default=None,
                    help="override mesh (tests), e.g. --mesh-shape 2 4")
    ap.add_argument("--mesh-axes", type=str, nargs="*", default=None)
    ap.add_argument("--variant", nargs="*", default=[])
    ap.add_argument("--seg-counts", type=int, nargs="*", default=None)
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args(argv)

    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   variant=parse_variant(args.variant),
                   mesh_shape=args.mesh_shape, mesh_axes=args.mesh_axes,
                   seg_counts=args.seg_counts)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)
    if res["status"] != "ok" and res["status"] != "inapplicable":
        sys.exit(1)


if __name__ == "__main__":
    main()
