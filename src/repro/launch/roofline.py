"""Roofline-term extraction from a compiled (dry-run) artifact.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the partitioned HLO
(``compiled.as_text()``) and sum the buffer sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, weighted by
the ring-algorithm traffic factor (all-reduce moves ~2x its payload).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field, asdict

import numpy as np

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (conservative single-link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

# result shape of a collective op line, e.g.:
#   %ag = bf16[2,4096,512]{...} all-gather(...)
_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

# traffic factor per op kind (ring algorithms, bytes on the wire per chip
# relative to the printed buffer size)
_FACTOR = {
    "all-gather": 1.0,        # result is the gathered buffer
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    if not dims:
        return b
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective buffer bytes (per partition; post-SPMD HLO shapes are
    per-device) weighted by ring traffic factors.

    `-start/-done` pairs are de-duplicated by only counting `-start` when
    both forms appear for async collectives (the regex tags both; `-done`
    results repeat the buffer)."""
    stats = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        # skip the -done halves of async pairs
        tail = hlo_text[m.end(3):m.end(3) + 6]
        if hlo_text[m.start():m.end()].endswith("-done("):
            continue
        nbytes = _shape_bytes(dtype, dims) * _FACTOR[kind]
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # whole-step, all chips
    hlo_bytes: float
    collective_bytes_per_chip: float
    collectives: dict
    collective_counts: dict
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    bytes_per_device: float = 0.0
    note: str = ""

    def finalize(self):
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes_per_chip / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / self.hlo_flops
                             if self.hlo_flops else 0.0)
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        self.roofline_fraction = ideal / bound if bound > 0 else 0.0
        return self

    def to_json(self) -> str:
        return json.dumps(asdict(self), default=float)


def analyze(*, arch, shape, mesh_desc, chips, cost, hlo_text, model_flops,
            bytes_per_device=0.0, note="") -> Roofline:
    """cost: compiled.cost_analysis() dict (per-partition on SPMD modules —
    we scale to all chips); hlo_text: compiled.as_text()."""
    flops = float(cost.get("flops", 0.0))
    acc_bytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops=flops * chips,
        hlo_bytes=acc_bytes * chips,
        collective_bytes_per_chip=coll.total_bytes,
        collectives={k: float(v) for k, v in coll.bytes_by_kind.items()},
        collective_counts=dict(coll.count_by_kind),
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
        note=note,
    )
    return r.finalize()
