"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 chips (pod, data, model); 'pod' is the
    DCI-connected outer data axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for tests (e.g. (2, 4) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def device_count_required(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
