"""Training launcher: --arch <id> with optional host-device mesh.

    # CPU-sized smoke run:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --preset reduced --steps 50

    # sharded run on host devices (sets the device count BEFORE jax init):
    REPRO_TRAIN_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --preset reduced --steps 20 --mesh 2 4

On a real TPU slice, drop REPRO_TRAIN_DEVICES and pass the slice topology
as --mesh; restarts resume from --ckpt-dir automatically (ExpoCloud
reassignment-compatible, see examples/train_lm.py for the task wrapper).
"""
import os

if os.environ.get("REPRO_TRAIN_DEVICES"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=" +
                               os.environ["REPRO_TRAIN_DEVICES"]).strip()

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--preset", choices=["reduced", "full"],
                    default="reduced")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", type=int, nargs="*", default=None,
                    help="e.g. --mesh 2 4 for a (data=2, model=4) mesh")
    ap.add_argument("--no-zero1", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced_config
    from repro.data.synthetic import data_config_for
    from repro.train.loop import TrainJob, run_training

    cfg = (reduced_config(args.arch) if args.preset == "reduced"
           else get_config(args.arch))
    dc = data_config_for(cfg, seq_len=args.seq, batch_size=args.batch)
    rules = None
    if args.mesh:
        from repro.launch.mesh import make_mesh
        from repro.sharding.rules import make_rules

        axes = ("data", "model")[:len(args.mesh)] if len(args.mesh) <= 2 \
            else ("pod", "data", "model")
        rules = make_rules(make_mesh(tuple(args.mesh), axes))
    job = TrainJob(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   ckpt_dir=args.ckpt_dir, base_lr=args.lr,
                   optimizer=args.optimizer, zero1=not args.no_zero1,
                   log_every=max(1, args.steps // 10))
    hist, final, _ = run_training(cfg, dc, job, rules=rules)
    print(f"[launch.train] {args.arch} ({args.preset}) done at step {final}; "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
