"""Drive the full (arch x shape x mesh) dry-run grid through ExpoCloud.

    PYTHONPATH=src python -m repro.launch.sweep_dryrun \
        --mesh single --mode probe --out dryrun_results [--archs a b ...]

The grid is exactly the paper's use case: tasks ordered easiest->hardest by
static hardness, a deadline per cell, timeouts domino-pruning dominating
cells, results in a tabular report.  Cells run as subprocesses via the
unified Experiment facade on the local engine (one worker per client —
compiles are single-core here).

mode=full   full-config lower+compile per cell (the dry-run proof)
mode=probe  unrolled small-layer-count probes (roofline extrapolation)
"""
from __future__ import annotations

import argparse
import time

from repro.configs import cells, get_config
from repro.core.experiment import Experiment
from repro.core.server import ServerConfig
from repro.core.sweep import DryRunCellTask, probe_plans


def build_tasks(archs, shapes, meshes, modes, deadline, out_dir,
                variant=None):
    tasks = []
    for arch, shape in cells():
        if archs and arch not in archs:
            continue
        if shapes and shape not in shapes:
            continue
        for mesh in meshes:
            if "full" in modes:
                tasks.append(DryRunCellTask(
                    arch, shape, mesh, None, variant, deadline, out_dir))
            if "probe" in modes and mesh == "single":
                for plan in probe_plans(arch):
                    tasks.append(DryRunCellTask(
                        arch, shape, mesh, plan,
                        dict(variant or {}, unroll=1), deadline, out_dir))
    return tasks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--mode", choices=["full", "probe", "both"],
                    default="both")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    ap.add_argument("--deadline", type=float, default=1800.0)
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--variant", nargs="*", default=[])
    ap.add_argument("--max-clients", type=int, default=1)
    ap.add_argument("--scale", choices=["fixed", "demand"], default="fixed",
                    help="fleet-scaling policy (see repro.core.policy)")
    ap.add_argument("--budget-cap", type=float, default=None,
                    help="stop creating instances when the projected spend "
                         "(wall-clock-proxy instance-seconds) nears the cap")
    ap.add_argument("--shards", type=int, default=1,
                    help="split the sweep across K scheduler shards on the "
                         "virtual-clock simulator (cells still execute, at "
                         "their virtual completion instants, modelled as "
                         "--sim-cell-s seconds each); per-shard CostMeter "
                         "summaries are merged into one ResultsTable cost "
                         "account.  shards=1 keeps the local engine")
    ap.add_argument("--sim-cell-s", type=float, default=60.0,
                    help="virtual seconds one cell occupies a worker in the "
                         "sharded (simulator) schedule, for makespan/cost "
                         "accounting")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    modes = ["full", "probe"] if args.mode == "both" else [args.mode]
    variant = dict(kv.split("=", 1) for kv in args.variant) \
        if args.variant else None

    tasks = build_tasks(args.archs, args.shapes, meshes, modes,
                        args.deadline, args.out, variant)
    print(f"[sweep] {len(tasks)} cells queued")
    config = ServerConfig(
        max_clients=args.max_clients,
        use_backup=False,                  # paper: no backup locally
        health_update_limit=60.0,
        instance_max_non_active_time=120.0,
        out_dir=args.out + "/expocloud",
        workers_hint=1,
        scale_policy=args.scale,
        budget_cap=args.budget_cap,
    )
    if args.shards > 1:
        # sharded sweep: K scheduler shards on one virtual clock.  Cells
        # still execute (the simulated worker pool runs each task at its
        # virtual completion instant); the clock models every cell as
        # --sim-cell-s seconds, so makespan and the merged cost summary
        # are schedule estimates, not wall measurements
        import dataclasses

        from repro.core.sim import SimParams
        for t in tasks:
            t.sim_duration = args.sim_cell_s
        # per-shard servers must not race on one out_dir (each would
        # write its partial table over the others') — the merged table
        # below is the authoritative sharded output
        config = dataclasses.replace(config, out_dir=None)
        exp = Experiment(tasks, engine="sim",
                         sim=SimParams(client_workers=1, seed=0),
                         shards=args.shards, config=config)
    else:
        exp = Experiment(tasks, engine="local",
                         engine_cfg={"n_workers_per_client": 1},
                         config=config)
    t0 = time.time()
    with exp.run() as run:
        table = run.results(poll_sleep=0.2)
    print(f"[sweep] done in {time.time()-t0:.0f}s")
    print(table.to_csv())
    if table.cost is not None:
        shard_note = f", {args.shards} shards" if args.shards > 1 else ""
        print(f"[sweep] cost: {table.cost['total']:.0f} instance-seconds "
              f"(wall-clock proxy, {table.cost['instances']} instances"
              f"{shard_note})")


if __name__ == "__main__":
    main()
