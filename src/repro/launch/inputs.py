"""``input_specs`` — ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input of every
(arch x shape) cell, plus the abstract param/optimizer/cache trees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig
from repro.models import lm
from repro.models.params import abstract_params
from repro.sharding.rules import ShardingRules
from repro.sharding.zero import opt_state_shardings


def _sds(shape, dtype, rules: ShardingRules | None, logical):
    sharding = rules.sharding(logical, shape) if rules is not None else None
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sharding)


def batch_specs(cfg, shape: ShapeConfig, rules: ShardingRules | None = None):
    """The data batch for a cell (train/prefill: full sequences;
    decode: one new token per sequence + positions)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind in ("train", "prefill"):
        tok_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
        tok_logical = ("batch", "seq", None) if cfg.num_codebooks \
            else ("batch", "seq")
        specs["tokens"] = _sds(tok_shape, jnp.int32, rules, tok_logical)
        if cfg.vision_stub:
            N = cfg.num_image_tokens
            specs["image_embeds"] = _sds((B, N, cfg.d_model), jnp.bfloat16,
                                         rules, ("batch", None, "embed"))
            specs["image_positions"] = _sds((B, N), jnp.int32, rules,
                                            ("batch", None))
    else:  # decode
        tok_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks else (B, 1)
        tok_logical = ("batch", None, None) if cfg.num_codebooks \
            else ("batch", None)
        specs["tokens"] = _sds(tok_shape, jnp.int32, rules, tok_logical)
        specs["pos"] = _sds((B,), jnp.int32, rules, ("batch",))
    return specs


def cache_specs(cfg, shape: ShapeConfig, rules: ShardingRules | None = None):
    assert shape.kind == "decode"
    descr = lm.make_cache(cfg, shape.global_batch, shape.seq_len)
    return abstract_params(descr, rules)


def param_specs_abstract(cfg, rules: ShardingRules | None = None):
    return abstract_params(lm.make_lm(cfg), rules)


def opt_specs_abstract(cfg, opt, opt_name: str,
                       rules: ShardingRules | None = None, zero1: bool = True):
    """Abstract optimizer state with ZeRO-1 shardings."""
    params_abs = param_specs_abstract(cfg, rules)
    state_abs = jax.eval_shape(opt.init, params_abs)
    if rules is None:
        return state_abs
    shardings = opt_state_shardings(opt_name, lm.make_lm(cfg), rules,
                                    zero1=zero1)
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_abs, shardings)


def input_specs(cfg, shape: ShapeConfig, rules: ShardingRules | None = None,
                opt=None, opt_name: str = "adamw", zero1: bool = True):
    """Everything the jitted step needs, as ShapeDtypeStructs.

    train  -> (params, opt_state, batch, step)
    prefill-> (params, batch)
    decode -> (params, batch, cache)
    """
    params = param_specs_abstract(cfg, rules)
    batch = batch_specs(cfg, shape, rules)
    if shape.kind == "train":
        assert opt is not None
        opt_state = opt_specs_abstract(cfg, opt, opt_name, rules, zero1)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return (params, opt_state, batch, step)
    if shape.kind == "prefill":
        return (params, batch)
    return (params, batch, cache_specs(cfg, shape, rules))
