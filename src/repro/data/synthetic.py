"""Deterministic synthetic LM data.

The stream is a *function of (seed, step)* — no files, no cursors — so the
iterator's checkpoint state is a single integer and restore-after-failure
reproduces the exact batch sequence (a requirement for deterministic
elastic restarts).  Tokens follow a noisy autoregressive walk so small
models show a real, monotone loss decrease (unlike uniform noise).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    num_codebooks: int = 0
    # VLM stub
    num_image_tokens: int = 0
    d_model: int = 0


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng([cfg.seed, step])


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Deterministic batch for a given step."""
    rng = _rng(cfg, step)
    B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    start = rng.integers(0, V, size=shape[:1] + shape[2:])
    stride = rng.integers(1, 7, size=shape[:1] + shape[2:])
    noise = (rng.random(shape) < 0.05) * rng.integers(0, V, size=shape)
    t = np.arange(S)
    walk = ((start[:, None, :] + stride[:, None, :] * t[None, :, None]) % V
            if cfg.num_codebooks
            else (start[:, None] + stride[:, None] * t[None, :]) % V)
    tokens = np.where(noise > 0, noise, walk).astype(np.int32)
    batch = {"tokens": tokens}
    if cfg.num_image_tokens:
        batch["image_embeds"] = rng.standard_normal(
            (B, cfg.num_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
        batch["image_positions"] = np.tile(
            np.arange(cfg.num_image_tokens, dtype=np.int32), (B, 1))
    return batch


class SyntheticIterator:
    """Checkpointable iterator: state == next step index."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = batch_at(self.cfg, self.step)
        self.step += 1
        return b

    def state(self) -> int:
        return self.step

    def restore(self, state: int):
        self.step = int(state)


def data_config_for(model_cfg, seq_len: int, batch_size: int,
                    seed: int = 0) -> DataConfig:
    return DataConfig(
        vocab_size=model_cfg.vocab_size,
        seq_len=seq_len,
        batch_size=batch_size,
        seed=seed,
        num_codebooks=model_cfg.num_codebooks,
        num_image_tokens=(model_cfg.num_image_tokens
                          if model_cfg.vision_stub else 0),
        d_model=model_cfg.d_model,
    )
