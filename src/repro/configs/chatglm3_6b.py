"""chatglm3-6b — dense, 2d (partial, interleaved) RoPE, GQA kv=2.
[arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rotary_pct=0.5,          # rotary applied to half the head dim
    rope_interleaved=True,   # GLM 2d-RoPE pairing
    rope_theta=10000.0,
    act="silu",
)
