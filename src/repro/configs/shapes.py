"""Assigned input shapes.

Each LM shape is seq_len x global_batch.  ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` requires a sub-quadratic architecture and is
skipped (by design, recorded) for pure full-attention archs.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def shape_applicable(cfg, shape: ShapeConfig) -> bool:
    """long_500k only runs for sub-quadratic (SSM / hybrid) families."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
