"""qwen3-4b — dense, qk-norm, GQA kv=8, large vocab. [hf:Qwen/Qwen3-4B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,     # decoupled from d_model/num_heads in Qwen3
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    act="silu",
)
