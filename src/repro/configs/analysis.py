"""Static analysis of configs: parameter counts, per-step model FLOPs,
cache bytes.  Used for (a) ExpoCloud task hardness of exploration cells,
(b) MODEL_FLOPS in the roofline report (6·N·D dense / 6·N_active·D MoE),
(c) sanity checks in tests.

All counts are exact from the config algebra — no arrays are built.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig


def _attn_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.attention_kind == "mla":
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        n = 0
        n += d * m.q_lora_rank + m.q_lora_rank  # q down (+norm)
        n += m.q_lora_rank * cfg.num_heads * qk_head  # q up
        n += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank
        n += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
        n += cfg.num_heads * m.v_head_dim * d  # o proj
        return n
    if cfg.attention_kind == "none":
        return 0
    n = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    if cfg.qk_norm:
        n += 2 * cfg.head_dim
    return n


def _mamba_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nheads = s.n_heads(d)
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    n = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
    n += conv_ch * s.d_conv + conv_ch  # conv1d + bias
    n += 2 * nheads  # A_log, D
    n += nheads  # dt_bias
    n += d_in  # gated norm
    n += d_in * d  # out_proj
    return n


def _dense_ffn_params(cfg: ModelConfig, width: int) -> int:
    # silu -> gated SwiGLU (gate+up+down); gelu -> classic 2-matrix MLP
    mats = 3 if cfg.act == "silu" else 2
    return mats * cfg.d_model * width


def _moe_ffn_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) params of one MoE FFN layer."""
    m = cfg.moe
    per_exp = 3 * cfg.d_model * m.d_ff_expert
    router = cfg.d_model * m.num_experts
    shared = m.num_shared_experts * per_exp
    total = m.num_experts * per_exp + router + shared
    active = m.top_k * per_exp + router + shared
    return total, active


def _layer_kinds(cfg: ModelConfig):
    """Yield (mixer, ffn) per layer: mixer in {attn,mamba,none},
    ffn in {dense,moe,none}."""
    for i in range(cfg.num_layers):
        if cfg.hybrid_block:
            mixer = "attn" if (i % cfg.hybrid_block) == cfg.hybrid_attn_index else "mamba"
        elif cfg.attention_free:
            mixer = "mamba"
        else:
            mixer = "attn"
        if cfg.family == "ssm":
            ffn = "none"
        elif cfg.is_moe_layer(i):
            ffn = "moe"
        else:
            ffn = "dense"
        yield mixer, ffn


@dataclass(frozen=True)
class ParamCounts:
    total: int
    active: int           # per-token active params (MoE top-k)
    embedding: int


def param_counts(cfg: ModelConfig) -> ParamCounts:
    d = cfg.d_model
    emb = cfg.vocab_size * d * max(cfg.num_codebooks, 1)
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * d * max(cfg.num_codebooks, 1)
    total = emb + head + d  # final norm
    active = emb + head + d
    dense_w = cfg.d_ff_dense or cfg.d_ff
    for mixer, ffn in _layer_kinds(cfg):
        lt = la = 2 * d  # two norms
        if mixer == "attn":
            p = _attn_params(cfg)
            lt += p
            la += p
        elif mixer == "mamba":
            p = _mamba_params(cfg)
            lt += p
            la += p
        if ffn == "dense":
            p = _dense_ffn_params(cfg, dense_w)
            lt += p
            la += p
        elif ffn == "moe":
            t, a = _moe_ffn_params(cfg)
            lt += t
            la += a
        total += lt
        active += la
    if cfg.mtp_depth:
        # each MTP module: 1 transformer layer + projection (2d -> d)
        per = _attn_params(cfg) + _dense_ffn_params(cfg, dense_w) + 2 * d * d + 3 * d
        total += cfg.mtp_depth * per
        active += cfg.mtp_depth * per
    return ParamCounts(total=total, active=active, embedding=emb + head)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per assignment,
    where D is tokens processed by the step.

    train counts fwd+bwd (the 6x); prefill/decode count forward only (2x).
    Decode steps process global_batch tokens (one new token each).
    """
    pc = param_counts(cfg)
    n = pc.active - pc.embedding  # FLOPs-relevant params exclude embed gather
    # logits matmul params do contribute:
    n += cfg.vocab_size * cfg.d_model * max(cfg.num_codebooks, 1)
    if shape.kind == "train":
        tokens = shape.tokens
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.tokens
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    flops = mult * n * tokens
    # attention score/value FLOPs (not in 6ND); count for honesty
    if not cfg.attention_free:
        attn_layers = sum(1 for m, _ in _layer_kinds(cfg) if m == "attn")
        if cfg.attention_kind == "mla":
            qk_head = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
            per_tok = cfg.num_heads * (qk_head + cfg.mla.v_head_dim)
        else:
            per_tok = cfg.num_heads * 2 * cfg.head_dim
        if shape.kind == "train":
            # causal: S/2 average context
            sc = shape.seq_len / 2
            flops += 6.0 * attn_layers * per_tok * sc * shape.tokens
        elif shape.kind == "prefill":
            sc = shape.seq_len / 2
            flops += 2.0 * attn_layers * per_tok * sc * shape.tokens
        else:
            flops += 2.0 * attn_layers * per_tok * shape.seq_len * shape.global_batch
    return flops


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig, dtype_bytes: int = 2) -> int:
    """Decode-path cache bytes (KV cache + SSM/conv states), global."""
    b, s = shape.global_batch, shape.seq_len
    total = 0
    for mixer, _ in _layer_kinds(cfg):
        if mixer == "attn":
            per_tok = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                       if cfg.attention_kind == "mla"
                       else 2 * cfg.num_kv_heads * cfg.head_dim)
            total += b * s * per_tok * dtype_bytes
        elif mixer == "mamba":
            ssm = cfg.ssm
            d_in = ssm.d_inner(cfg.d_model)
            nheads = ssm.n_heads(cfg.d_model)
            conv_ch = d_in + 2 * ssm.n_groups * ssm.d_state
            total += b * (ssm.d_conv - 1) * conv_ch * dtype_bytes
            total += b * nheads * ssm.head_dim * ssm.d_state * 4  # fp32 state
    return total


def hardness_tuple(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """The ExpoCloud hardness of an exploration cell: componentwise-comparable
    proxies for how expensive the cell is to lower/compile/run.
    (total params, step model-FLOPs, cache bytes, seq_len, tokens)
    """
    pc = param_counts(cfg)
    return (
        pc.total,
        int(model_flops(cfg, shape)),
        kv_cache_bytes(cfg, shape),
        shape.seq_len,
        shape.tokens,
    )
