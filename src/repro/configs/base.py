"""Config dataclasses for the model zoo.

Every assigned architecture is expressed as a single ``ModelConfig``; the
model-builder in :mod:`repro.models.lm` interprets the flags.  Configs are
frozen dataclasses so they can be hashed into jit static args and into
ExpoCloud task hardness tuples.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    # Which layers are MoE: layer i is MoE iff i >= first_k_dense and
    # (i - first_k_dense) % every == 0.
    first_k_dense: int = 0
    every: int = 1
    aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25
    # 'softmax' (classic top-k) or 'sigmoid' (DeepSeek-V3 style scoring with
    # normalised top-k weights).
    scoring: str = "softmax"
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention options -------------------------------------------------
    attention_kind: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0      # fraction of head_dim that is rotary
    rope_interleaved: bool = False  # GLM-style 2d/interleaved RoPE pairs

    # --- sub-configs --------------------------------------------------------
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    mla: MLAConfig | None = None

    # --- hybrid (Jamba) -----------------------------------------------------
    # If >0: layers are grouped into super-blocks of this many layers; the
    # attention layer sits at ``hybrid_attn_index`` within each block and all
    # other mixers are Mamba.
    hybrid_block: int = 0
    hybrid_attn_index: int = 4

    # --- modality frontends (STUBS per assignment) ---------------------------
    num_codebooks: int = 0       # musicgen: EnCodec codebooks
    vision_stub: bool = False    # phi-3-vision: precomputed patch embeds
    num_image_tokens: int = 0    # stand-in image token count per sample

    # --- misc ---------------------------------------------------------------
    act: str = "silu"            # silu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    mtp_depth: int = 0           # DeepSeek multi-token-prediction modules
    mtp_loss_weight: float = 0.3
    # Dense FFN width for dense layers when the MoE config only covers a
    # subset of layers (DeepSeek first-k-dense).  0 -> use d_ff.
    d_ff_dense: int = 0

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def rotary_dim(self) -> int:
        d = int(self.head_dim * self.rotary_pct)
        return d - (d % 2)

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        if idx < self.moe.first_k_dense:
            return False
        return (idx - self.moe.first_k_dense) % self.moe.every == 0

    @property
    def attention_free(self) -> bool:
        return self.attention_kind == "none"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch has a long-context (500k) path: SSM or hybrid."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> ModelConfig:
        return dataclasses.replace(self, **kw)
