"""musicgen-medium — decoder-only LM over EnCodec tokens (4 codebooks,
delay pattern).  The EnCodec frontend is a STUB per the assignment; the
backbone consumes/predicts codebook token ids.  [arXiv:2306.05284]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    rope_theta=10000.0,
    act="gelu",
)
