"""deepseek-v3-671b — MLA + fine-grained MoE (1 shared + 256 routed, top-8)
+ MTP. First 3 layers dense.  [arXiv:2412.19437]
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,    # MLA: all heads share the compressed latent
    head_dim=128,        # qk_nope head dim; see MLAConfig for the full split
    d_ff=18432,          # dense-layer FFN width (first 3 layers)
    d_ff_dense=18432,
    vocab_size=129280,
    attention_kind="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        num_shared_experts=1,
        top_k=8,
        d_ff_expert=2048,
        first_k_dense=3,
        every=1,
        scoring="sigmoid",   # DeepSeek-V3 sigmoid scoring + normalised top-k
        aux_loss_coef=0.0001,
    ),
    rope_theta=10000.0,
    act="silu",
    mtp_depth=1,
)
