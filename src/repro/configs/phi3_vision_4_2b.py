"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (STUB: the
assignment specifies the transformer backbone only; ``input_specs`` provides
precomputed patch embeddings).  [hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    act="silu",
    vision_stub=True,
    num_image_tokens=576,   # one 336px CLIP tile worth of patch embeddings
)
