"""olmoe-1b-7b — 64-expert top-8 MoE, MHA. [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,           # per-expert width
    vocab_size=50304,
    qk_norm=True,        # OLMoE uses QK-Norm
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=0,
        top_k=8,
        d_ff_expert=1024,
        first_k_dense=0,
        every=1,
        scoring="softmax",
        aux_loss_coef=0.01,
    ),
    rope_theta=10000.0,
    act="silu",
)
