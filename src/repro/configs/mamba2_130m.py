"""mamba2-130m — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,              # no FFN: the Mamba block is the whole layer
    vocab_size=50280,
    attention_kind="none",
    ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, d_conv=4, expand=2),
    act="silu",
)
