"""jamba-v0.1-52b — hybrid Mamba/attention 7:1 interleave with MoE (16e top-2)
on every other layer.  [arXiv:2403.19887]

Layout: 4 super-blocks x 8 layers; the attention mixer sits at in-block
index 4, all other mixers are Mamba.  MoE FFN on odd in-block indices.
Jamba uses Mamba-1 cells; we express them in the SSD (state-space duality)
formulation of Mamba-2 [arXiv:2405.21060] with d_state=16 — see
DESIGN.md "What changed vs. the paper".
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    hybrid_block=8,
    hybrid_attn_index=4,
    moe=MoEConfig(
        num_experts=16,
        num_shared_experts=0,
        top_k=2,
        d_ff_expert=14336,
        first_k_dense=1,   # MoE on odd layer indices
        every=2,
        scoring="softmax",
        aux_loss_coef=0.01,
    ),
    ssm=SSMConfig(d_state=16, head_dim=64, n_groups=1, d_conv=4, expand=2),
    rope_theta=10000.0,  # Jamba has no positional encoding on attn; harmless
    rotary_pct=0.0,      # -> NoPE on the attention layers
    act="silu",
)
