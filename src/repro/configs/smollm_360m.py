"""smollm-360m — llama-arch small dense LM. [hf:HuggingFaceTB/SmolLM-360M]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=10000.0,
    act="silu",
    tie_embeddings=True,
)
