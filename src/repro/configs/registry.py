"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, MLAConfig
from repro.configs.shapes import SHAPES, ShapeConfig, get_shape, shape_applicable

_ARCH_MODULES = {
    "smollm-360m": "repro.configs.smollm_360m",
    "granite-20b": "repro.configs.granite_20b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4_2b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "musicgen-medium": "repro.configs.musicgen_medium",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def cells(include_inapplicable: bool = False):
    """All (arch, shape) cells of the assigned grid, in registry order."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if include_inapplicable or shape_applicable(cfg, shape):
                out.append((arch, shape.name))
    return out


def reduced_config(name: str) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps every structural feature of the full config (GQA ratio, MLA, MoE
    top-k, hybrid interleave, codebooks ...) at toy width/depth.
    """
    cfg = get_config(name)
    kw: dict = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        vocab_size=256,
        tie_embeddings=cfg.tie_embeddings,
    )
    if cfg.attention_kind == "gqa":
        # preserve the q:kv ratio where possible
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        kv = max(1, 4 // ratio) if ratio <= 4 else 1
        kw.update(num_heads=kv * min(ratio, 4), num_kv_heads=kv, head_dim=32)
    if cfg.d_ff:
        kw.update(d_ff=256)
    if cfg.d_ff_dense:
        kw.update(d_ff_dense=256)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk=32
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=64,
            kv_lora_rank=32,
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=32,
        )
        kw.update(num_heads=4, num_kv_heads=4, head_dim=32)
    if cfg.hybrid_block:
        kw.update(num_layers=8, hybrid_block=4, hybrid_attn_index=2)
        kw["moe"] = dataclasses.replace(kw["moe"], first_k_dense=1, every=2)
    if cfg.num_image_tokens:
        kw.update(num_image_tokens=8)
    if cfg.mtp_depth:
        kw.update(mtp_depth=1)
    return cfg.replace(**kw)


REDUCED_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")


def segment_counts(cfg) -> list[int]:
    """Scanned-unit counts per segment (layers, or super-blocks for hybrid).
    Mirrors repro.models.lm.segments."""
    if cfg.hybrid_block:
        return [cfg.num_layers // cfg.hybrid_block]
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return [cfg.moe.first_k_dense,
                cfg.num_layers - cfg.moe.first_k_dense]
    return [cfg.num_layers]


def with_segment_counts(cfg: ModelConfig, counts: list[int]) -> ModelConfig:
    """Rebuild the config with new scanned-unit counts per segment (for
    unrolled roofline probes — see launch/sweep_dryrun.py)."""
    cur = segment_counts(cfg)
    assert len(counts) == len(cur), (counts, cur)
    if cfg.hybrid_block:
        return cfg.replace(num_layers=counts[0] * cfg.hybrid_block)
    if cfg.moe is not None and cfg.moe.first_k_dense:
        fk, nm = counts
        return cfg.replace(
            num_layers=fk + nm,
            moe=dataclasses.replace(cfg.moe, first_k_dense=fk))
    return cfg.replace(num_layers=counts[0])

__all__ = [
    "ARCH_IDS",
    "get_config",
    "get_shape",
    "cells",
    "reduced_config",
    "shape_applicable",
    "REDUCED_SHAPE",
    "SHAPES",
]
