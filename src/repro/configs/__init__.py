from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, MLAConfig
from repro.configs.shapes import SHAPES, ShapeConfig, get_shape, shape_applicable
from repro.configs.registry import (
    ARCH_IDS,
    REDUCED_SHAPE,
    cells,
    get_config,
    reduced_config,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "MLAConfig",
    "SHAPES",
    "ShapeConfig",
    "get_shape",
    "shape_applicable",
    "ARCH_IDS",
    "REDUCED_SHAPE",
    "cells",
    "get_config",
    "reduced_config",
]
