"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

TPU-native design:
  * grid = (batch, heads, S/chunk); the chunk axis is the innermost
    'arbitrary' dimension and the running SSM state h [P, N] lives in VMEM
    scratch across chunk steps — the sequential inter-chunk recurrence maps
    onto the TPU grid-carry idiom instead of a GPU block-parallel scan.
  * per-chunk work is two MXU matmuls (C·Bᵀ intra-chunk quadratic term and
    the state in/out projections) over [L, N]x[N, L] / [L, N]x[N, P] blocks;
    L=chunk and N, P are 64–128 so everything is MXU-shaped.
  * B/C group mapping (GQA-style G groups) happens in the index_map
    (h // heads_per_group), no replication materialised.
  * fp32 state and decay math in-kernel (mixed_precision_sensitive:
    cumsum + exp), inputs/outputs in the model dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _kernel(x_ref, dt_ref, A_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref,
            h_scr, *, chunk: int, has_h0: bool):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)
    L = chunk

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = (h0_ref[0, 0].astype(jnp.float32) if has_h0
                      else jnp.zeros_like(h_scr))

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [L]
    A = A_ref[0]                                    # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)      # [L, N]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)      # [L, N]

    dA = dt * A                                     # [L]
    cum = jnp.cumsum(dA)                            # [L]
    # intra-chunk: scores[l, s] = C_l·B_s · exp(cum_l - cum_s) · dt_s, s<=l
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    scores = jnp.where(li >= si, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [L, P]

    # inter-chunk: y += exp(cum_l) * C_l · h_in   (h: [P, N])
    h_in = h_scr[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h_out = exp(cum_L) h_in + sum_s exp(cum_L - cum_s) dt_s x_s B_sᵀ
    w = jnp.exp(cum[-1] - cum) * dt                 # [L]
    state_in = jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # [P, N]
    h_scr[...] = jnp.exp(cum[-1]) * h_in + state_in

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _write_state():
        hT_ref[0, 0] = h_scr[...]


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, h0=None,
             return_final_state: bool = False, interpret: bool = False):
    """x: [B,S,H,P], dt: [B,S,H], A: [H], Bm/Cm: [B,S,G,N].

    Returns y [B,S,H,P] (and final state [B,H,P,N] fp32 if requested)."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0, (H, G)
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    has_h0 = h0 is not None
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    kernel = functools.partial(_kernel, chunk=chunk, has_h0=has_h0)
    y, hT = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, h0)
    if return_final_state:
        return y, hT
    return y
