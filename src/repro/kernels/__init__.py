# Pallas TPU kernels for the substrate's compute hot-spots (the ExpoCloud
# paper itself is orchestration-level and has no kernel contribution — see
# DESIGN.md):
#   flash_attention.py — GQA flash attention (BlockSpec VMEM tiling, online
#                        softmax in VMEM scratch across the KV grid axis)
#   ssd_scan.py        — Mamba-2 SSD chunked scan (state carried in VMEM
#                        scratch across the chunk grid axis)
#   ops.py             — jit'd wrappers with backend dispatch
#   ref.py             — pure-jnp oracles
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
