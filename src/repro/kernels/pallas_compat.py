"""Pallas API-drift shims shared by the TPU kernels.

jax >= 0.5 names the TPU compiler-options struct
``pltpu.CompilerParams``; older releases call it ``TPUCompilerParams``.
Resolving it once here keeps every kernel importable on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
