"""Decode attention (Sq=1, GQA, ragged KV) as a Pallas TPU kernel.

The prefill-shaped ``kernels/flash_attention.py`` wastes its whole
(Sq/block_q) grid axis on decode, where every slot contributes exactly one
query token.  This kernel is shaped for the serving fast path instead:

  * grid = (batch, kv_heads, Sk/block_k) — no query axis at all.  The KV
    dimension is the innermost 'arbitrary' axis so the online-softmax
    accumulators live in VMEM scratch across KV steps.
  * GQA is handled *inside* the kernel: the query block is the [G, D]
    group of heads sharing one KV head, so the [B, 1, H, D] query never
    replicates K/V and the per-step matmuls are [G, D] x [D, block_k].
  * ragged batches: ``kv_len`` is a per-slot [B] vector read from SMEM.
    Whole KV blocks past a slot's live length are skipped with ``pl.when``
    (zero compute for the dead cache tail — continuous batching leaves
    every slot at a different fill level), partial blocks are masked.

Non-dividing Sk is handled by zero-padding K/V up to a block multiple in
the wrapper; the pad region sits beyond every ``kv_len`` so the masking
covers it.  The grid divisibility is asserted after padding (expolint
pallas-rules).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0, 0]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)            # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [G, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # [bk, Dv]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, *, scale: float | None = None,
                     block_k: int = 128, interpret: bool = False):
    """q: [B, H, D]; k: [B, Sk, K, D]; v: [B, Sk, K, Dv]; kv_len: [B] int32
    (per-slot live cache length, position p attended iff p < kv_len).
    Returns [B, H, Dv]."""
    Bsz, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    assert H % K == 0, (H, K)
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    block_k = min(block_k, Sk)
    pad = -Sk % block_k
    if pad:
        # padded tail sits at kpos >= Sk >= every kv_len -> fully masked
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Skp = Sk + pad
    assert Skp % block_k == 0, (Skp, block_k)
    grid = (Bsz, K, Skp // block_k)

    qg = q.reshape(Bsz, K, G, D)
    lens = jnp.asarray(kv_len, jnp.int32).reshape(Bsz, 1)
    kernel = functools.partial(_kernel, scale=scale, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dv), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, K, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, qg, k, v)
    return out.reshape(Bsz, H, Dv)
