"""Decode attention (Sq=1, GQA, ragged KV) as a Pallas TPU kernel.

The prefill-shaped ``kernels/flash_attention.py`` wastes its whole
(Sq/block_q) grid axis on decode, where every slot contributes exactly one
query token.  This kernel is shaped for the serving fast path instead:

  * grid = (batch, kv_heads, Sk/block_k) — no query axis at all.  The KV
    dimension is the innermost 'arbitrary' axis so the online-softmax
    accumulators live in VMEM scratch across KV steps.
  * GQA is handled *inside* the kernel: the query block is the [G, D]
    group of heads sharing one KV head, so the [B, 1, H, D] query never
    replicates K/V and the per-step matmuls are [G, D] x [D, block_k].
  * ragged batches: ``kv_len`` is a per-slot [B] vector read from SMEM.
    Whole KV blocks past a slot's live length are skipped with ``pl.when``
    (zero compute for the dead cache tail — continuous batching leaves
    every slot at a different fill level), partial blocks are masked.

Non-dividing Sk is handled by zero-padding K/V up to a block multiple in
the wrapper; the pad region sits beyond every ``kv_len`` so the masking
covers it.  The grid divisibility is asserted after padding (expolint
pallas-rules).

``decode_attention_paged`` is the same online-softmax loop over a *paged*
KV pool: K/V live as [num_pages, page_size, K, D] blocks shared by all
slots, and each slot's page table row is scalar-prefetched to SMEM so the
BlockSpec index maps can steer the K/V DMA through it — the kernel reads
exactly the pages a slot owns, never a dense [B, Smax] stripe.  The grid
is (batch, kv_heads, pages-per-slot); whole pages past ``kv_len`` are
skipped with ``pl.when`` and the final partial page is masked by kpos.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0, 0]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)            # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [G, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # [bk, Dv]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, *, scale: float | None = None,
                     block_k: int = 128, interpret: bool = False):
    """q: [B, H, D]; k: [B, Sk, K, D]; v: [B, Sk, K, Dv]; kv_len: [B] int32
    (per-slot live cache length, position p attended iff p < kv_len).
    Returns [B, H, Dv]."""
    Bsz, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    assert H % K == 0, (H, K)
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    block_k = min(block_k, Sk)
    pad = -Sk % block_k
    if pad:
        # padded tail sits at kpos >= Sk >= every kv_len -> fully masked
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Skp = Sk + pad
    assert Skp % block_k == 0, (Skp, block_k)
    grid = (Bsz, K, Skp // block_k)

    qg = q.reshape(Bsz, K, G, D)
    lens = jnp.asarray(kv_len, jnp.int32).reshape(Bsz, 1)
    kernel = functools.partial(_kernel, scale=scale, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dv), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, K, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, qg, k, v)
    return out.reshape(Bsz, H, Dv)


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    npg = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]
    k_start = ip * page_size

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)            # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [ps, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [G, ps]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # [ps, Dv]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ip == npg - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_paged(q, k_pool, v_pool, page_table, kv_len, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """Sq=1 GQA decode attention against a paged KV pool.

    q: [B, H, D]; k_pool: [P, ps, K, D]; v_pool: [P, ps, K, Dv];
    page_table: [B, W] int32 (physical page backing each slot's logical
    page — prefetched to SMEM and read by the K/V index maps, so only a
    slot's own pages are ever DMA'd); kv_len: [B] int32 (position p
    attended iff p < kv_len; stale rows of partially-filled or
    unallocated pages are masked).  The page dimension is the innermost
    'arbitrary' grid axis — no ``//`` feeds the grid, the page-table
    width *is* the page count.  Returns [B, H, Dv]."""
    Bsz, H, D = q.shape
    page_size, K = k_pool.shape[1], k_pool.shape[2]
    Dv = v_pool.shape[-1]
    assert H % K == 0, (H, K)
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    W = page_table.shape[1]
    grid = (Bsz, K, W)

    qg = q.reshape(Bsz, K, G, D)
    # unmapped entries hold an out-of-range sentinel; clamp so the K/V
    # index maps never DMA past the pool (the rows are masked anyway)
    pt = jnp.minimum(jnp.asarray(page_table, jnp.int32),
                     k_pool.shape[0] - 1)
    lens = jnp.asarray(kv_len, jnp.int32)
    kernel = functools.partial(_paged_kernel, scale=scale,
                               page_size=page_size)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # page_table + kv_len land in SMEM
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, ip, pt, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, ip, pt, lens: (pt[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, Dv),
                         lambda b, h, ip, pt, lens: (pt[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda b, h, ip, pt, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Bsz, K, G, Dv), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pt, lens, qg, k_pool, v_pool)
    return out.reshape(Bsz, H, Dv)
