"""Flash attention (GQA) as a Pallas TPU kernel.

TPU-native design (not a CUDA port):
  * grid = (batch, q_heads, Sq/block_q, Sk/block_k); the KV dimension is the
    innermost 'arbitrary' grid axis so the online-softmax accumulators live
    in VMEM scratch across KV steps (TPU has no cross-core shared memory —
    the accumulation pattern replaces the CUDA warp-level reduction).
  * BlockSpecs tile Q/K/V into VMEM: (1, block_q, 1, head_dim) blocks keep
    the working set (~2·block·D + block_q·block_k fp32) well under 16 MB
    VMEM for 128x128 blocks at D<=256.
  * block_q/block_k default to 128 — MXU-aligned (128x128 systolic array).
  * GQA: the KV head index is derived in the index_map (h // group) so no
    K/V replication is materialised.
  * causal: whole KV blocks strictly above the diagonal are skipped with
    pl.when (zero compute), partial blocks are masked.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            q_offset: int, kv_len: int):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q + q_offset
    k_start = ik * block_k

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # [bk, Dv]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip whole blocks strictly above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    q_offset: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, Sq, H, D]; k, v: [B, Sk, K, Dk/Dv] -> [B, Sq, H, Dv]."""
    Bsz, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    assert H % K == 0, (H, K)
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    grid = (Bsz, H, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, q_offset=q_offset, kv_len=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, Dv),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dv),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, Sq, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
