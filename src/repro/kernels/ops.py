"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy (this container is CPU-only; TPU is the *target*):

* backend == 'tpu'      -> compiled Pallas kernel (BlockSpec VMEM tiling)
* REPRO_PALLAS=interpret -> Pallas kernel body interpreted on CPU (tests)
* otherwise             -> pure-jnp reference (XLA), bit-for-bit the oracle

so models always call ``ops.flash_attention`` / ``ops.ssd_scan`` and get the
best available implementation.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "ref", "naive", "kernel"):
        return env
    if jax.default_backend() == "tpu":
        return "kernel"
    return "ref"


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    q_offset: int = 0, block_q: int = 128, block_k: int = 128):
    """GQA flash attention. q: [B,Sq,H,D], k/v: [B,Sk,K,D] -> [B,Sq,H,D]."""
    mode = _mode()
    if mode == "naive":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale,
                                 q_offset=q_offset)
    if mode == "ref":
        # blockwise (flash-style) XLA lowering — same algorithm as the
        # Pallas kernel, honest HBM profile on non-TPU backends.
        # (custom_vjp: positional args only)
        from repro.kernels.xla_flash import blockwise_attention

        return blockwise_attention(q, k, v, causal, scale, q_offset,
                                   max(block_k, 512))
    from repro.kernels import flash_attention as fk

    return fk.flash_attention(
        q, k, v, causal=causal, scale=scale, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=(mode == "interpret"),
    )


def decode_attention(q, k, v, kv_len, *, scale: float | None = None,
                     block_k: int = 128):
    """Sq=1 GQA decode attention over a ragged KV cache.

    q: [B,H,D], k/v: [B,Sk,K,D/Dv], kv_len: [B] int32 -> [B,H,Dv].  Same
    dispatch policy as ``flash_attention``: the pure-jnp reference is the
    XLA fallback on non-TPU backends, the Pallas decode kernel
    (``kernels/decode_attention.py``) runs on TPU or under
    ``REPRO_PALLAS=interpret``."""
    mode = _mode()
    if mode in ("ref", "naive"):
        return ref.decode_attention_ref(q, k, v, kv_len, scale=scale)
    from repro.kernels import decode_attention as dk

    return dk.decode_attention(q, k, v, kv_len, scale=scale, block_k=block_k,
                               interpret=(mode == "interpret"))


def decode_attention_paged(q, k_pool, v_pool, page_table, kv_len, *,
                           scale: float | None = None):
    """Sq=1 GQA decode attention against a paged KV pool.

    q: [B,H,D], k_pool/v_pool: [P,ps,K,D/Dv], page_table: [B,W] int32,
    kv_len: [B] int32 -> [B,H,Dv].  Same dispatch policy as
    ``decode_attention``: the pure-jnp reference (page gather + ragged
    dense attention) on non-TPU backends, the page-table Pallas kernel
    (scalar-prefetched tables steering the K/V DMA) on TPU or under
    ``REPRO_PALLAS=interpret``."""
    mode = _mode()
    if mode in ("ref", "naive"):
        return ref.decode_attention_paged_ref(q, k_pool, v_pool, page_table,
                                              kv_len, scale=scale)
    from repro.kernels import decode_attention as dk

    return dk.decode_attention_paged(q, k_pool, v_pool, page_table, kv_len,
                                     scale=scale,
                                     interpret=(mode == "interpret"))


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, h0=None,
             return_final_state: bool = False):
    """Mamba-2 SSD chunked scan. See kernels.ref.ssd_chunked_ref."""
    mode = _mode()
    if mode == "ref":
        return ref.ssd_chunked_ref(
            x, dt, A, Bm, Cm, chunk=chunk, h0=h0,
            return_final_state=return_final_state)
    from repro.kernels import ssd_scan as sk

    return sk.ssd_scan(
        x, dt, A, Bm, Cm, chunk=chunk, h0=h0,
        return_final_state=return_final_state,
        interpret=(mode == "interpret"),
    )
