"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy (this container is CPU-only; TPU is the *target*):

* backend == 'tpu'      -> compiled Pallas kernel (BlockSpec VMEM tiling)
* REPRO_PALLAS=interpret -> Pallas kernel body interpreted on CPU (tests)
* otherwise             -> pure-jnp reference (XLA), bit-for-bit the oracle

so models always call ``ops.flash_attention`` / ``ops.ssd_scan`` and get the
best available implementation.

Tuned-config plumbing (``repro.tune``): every block/chunk knob defaults
to ``None``, meaning "consult the persistent best-config cache for this
(kernel, shape bucket, dtype, backend), else use the built-in default".
A cache hit dispatches with the tuned blocks; a miss — including no
cache file at all — is byte-identical to the pre-tuning behavior.  An
explicit argument always wins over the cache.  Tuned values are
re-validated against the kernels' divisibility constraints here, so a
stale or foreign cache entry degrades to the default instead of
crashing the caller.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.tune import cache as _tune_cache

# built-in defaults served on a cache miss — mirrored by
# repro.tune.space.SPECS[*].defaults (the tuner's incumbents)
_DEFAULT_BLOCK_Q = 128
_DEFAULT_BLOCK_K = 128
_DEFAULT_DECODE_BLOCK_K = 128
_DEFAULT_CHUNK = 64


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "ref", "naive", "kernel"):
        return env
    if jax.default_backend() == "tpu":
        return "kernel"
    return "ref"


def _tuned(kernel: str, shape: dict, dtype) -> dict:
    """Best-config cache lookup for the current dispatch backend
    (empty dict on any miss)."""
    return _tune_cache.best_config(kernel, shape, str(dtype)) or {}


def _fit_block(value, dim: int, default: int) -> int:
    """Accept a tuned block size only if it satisfies the kernel's
    static constraint after the kernel's own min-clamp; otherwise fall
    back to the default (preserving the exact pre-tuning behavior,
    including its failure modes)."""
    v = int(value)
    clamped = min(v, dim)
    if clamped > 0 and dim % clamped == 0:
        return v
    return default


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    q_offset: int = 0, block_q: int | None = None,
                    block_k: int | None = None):
    """GQA flash attention. q: [B,Sq,H,D], k/v: [B,Sk,K,D] -> [B,Sq,H,D].

    ``block_q``/``block_k``: explicit value > tuned cache > 128."""
    mode = _mode()
    if mode == "naive":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale,
                                 q_offset=q_offset)
    Bsz, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    if block_q is None or block_k is None:
        cfg = _tuned("flash_attention",
                     {"b": Bsz, "s": Sk, "h": H, "kvh": K, "d": D}, q.dtype)
        if block_q is None:
            block_q = _fit_block(cfg.get("block_q", _DEFAULT_BLOCK_Q),
                                 Sq, _DEFAULT_BLOCK_Q)
        if block_k is None:
            block_k = _fit_block(cfg.get("block_k", _DEFAULT_BLOCK_K),
                                 Sk, _DEFAULT_BLOCK_K)
    if mode == "ref":
        # blockwise (flash-style) XLA lowering — same algorithm as the
        # Pallas kernel, honest HBM profile on non-TPU backends.
        # (custom_vjp: positional args only)
        from repro.kernels.xla_flash import blockwise_attention

        return blockwise_attention(q, k, v, causal, scale, q_offset,
                                   max(block_k, 512))
    from repro.kernels import flash_attention as fk

    return fk.flash_attention(
        q, k, v, causal=causal, scale=scale, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=(mode == "interpret"),
    )


def decode_attention(q, k, v, kv_len, *, scale: float | None = None,
                     block_k: int | None = None):
    """Sq=1 GQA decode attention over a ragged KV cache.

    q: [B,H,D], k/v: [B,Sk,K,D/Dv], kv_len: [B] int32 -> [B,H,Dv].  Same
    dispatch policy as ``flash_attention``: the pure-jnp reference is the
    XLA fallback on non-TPU backends, the Pallas decode kernel
    (``kernels/decode_attention.py``) runs on TPU or under
    ``REPRO_PALLAS=interpret``.  ``block_k``: explicit > tuned > 128
    (the wrapper zero-pads Sk, so any positive tuned value is valid)."""
    mode = _mode()
    if mode in ("ref", "naive"):
        return ref.decode_attention_ref(q, k, v, kv_len, scale=scale)
    if block_k is None:
        Bsz, H, D = q.shape
        Sk, K = k.shape[1], k.shape[2]
        cfg = _tuned("decode_attention",
                     {"b": Bsz, "sk": Sk, "h": H, "kvh": K, "d": D},
                     q.dtype)
        block_k = int(cfg.get("block_k", _DEFAULT_DECODE_BLOCK_K))
        if block_k <= 0:
            block_k = _DEFAULT_DECODE_BLOCK_K
    from repro.kernels import decode_attention as dk

    return dk.decode_attention(q, k, v, kv_len, scale=scale, block_k=block_k,
                               interpret=(mode == "interpret"))


def decode_attention_paged(q, k_pool, v_pool, page_table, kv_len, *,
                           scale: float | None = None):
    """Sq=1 GQA decode attention against a paged KV pool.

    q: [B,H,D], k_pool/v_pool: [P,ps,K,D/Dv], page_table: [B,W] int32,
    kv_len: [B] int32 -> [B,H,Dv].  Same dispatch policy as
    ``decode_attention``: the pure-jnp reference (page gather + ragged
    dense attention) on non-TPU backends, the page-table Pallas kernel
    (scalar-prefetched tables steering the K/V DMA) on TPU or under
    ``REPRO_PALLAS=interpret``.  The page geometry is fixed by the pool
    the caller built — the tuned ``page_size`` recommendation is
    consumed where the pool is constructed (``serve/engine.py``)."""
    mode = _mode()
    if mode in ("ref", "naive"):
        return ref.decode_attention_paged_ref(q, k_pool, v_pool, page_table,
                                              kv_len, scale=scale)
    from repro.kernels import decode_attention as dk

    return dk.decode_attention_paged(q, k_pool, v_pool, page_table, kv_len,
                                     scale=scale,
                                     interpret=(mode == "interpret"))


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int | None = None, h0=None,
             return_final_state: bool = False):
    """Mamba-2 SSD chunked scan. See kernels.ref.ssd_chunked_ref.

    ``chunk``: explicit value > tuned cache > 64.  Model code that bakes
    a semantic chunk into its config keeps passing it explicitly (and is
    byte-identical); pass ``None`` to opt into tuned chunking."""
    if chunk is None:
        Bsz, S, H, P = x.shape
        G, N = Bm.shape[2], Bm.shape[3]
        cfg = _tuned("ssd_scan",
                     {"b": Bsz, "s": S, "h": H, "p": P, "g": G, "n": N},
                     x.dtype)
        chunk = _fit_block(cfg.get("chunk", _DEFAULT_CHUNK), S,
                           _DEFAULT_CHUNK)
    mode = _mode()
    if mode == "ref":
        return ref.ssd_chunked_ref(
            x, dt, A, Bm, Cm, chunk=chunk, h0=h0,
            return_final_state=return_final_state)
    from repro.kernels import ssd_scan as sk

    return sk.ssd_scan(
        x, dt, A, Bm, Cm, chunk=chunk, h0=h0,
        return_final_state=return_final_state,
        interpret=(mode == "interpret"),
    )
