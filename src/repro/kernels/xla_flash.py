"""Blockwise (flash-style) attention in pure JAX/XLA with a flash backward.

This is the lowering path on non-TPU backends: algorithmically identical to
the Pallas kernel — online softmax over KV blocks, O(S·block) live memory,
bf16 matmul operands with fp32 accumulation (preferred_element_type), the
softmax/log-sum-exp domain in fp32.  The backward is a custom_vjp
implementing the FlashAttention backward (recompute p = exp(s - lse) per
block from saved (q, k, v, out, lse)) so autodiff does NOT store per-block
scan carries — matching the memory behaviour of the TPU kernel.
Validated against kernels.ref.attention_ref for values and grads.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def blockwise_attention(q, k, v, causal=True, scale=None, q_offset=0,
                        block_k=512):
    out, _ = _fwd_impl(q, k, v, causal, scale, q_offset, block_k)
    return out


def _prep(q, k, v, scale, block_k):
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    scale = D ** -0.5 if scale is None else scale
    block_k = int(os.environ.get("REPRO_FLASH_BLOCK", block_k))
    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = k.shape[1] // block_k
    qg = q.reshape(B, Sq, K, H // K, D)
    kb = k.reshape(B, nk, block_k, K, D)
    vb = v.reshape(B, nk, block_k, K, v.shape[-1])
    return qg, kb, vb, nk, block_k, Sk, scale


def _scores(qg, kk, kpos, qpos, Sk, causal, scale):
    """fp32-accumulated scores with masking. qg [B,Sq,K,G,D], kk [B,bk,K,D]."""
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kk,
                   preferred_element_type=jnp.float32) * scale
    valid = kpos[None, :] < Sk
    if causal:
        valid = jnp.logical_and(valid, kpos[None, :] <= qpos[:, None])
    return jnp.where(valid[None, :, None, None, :], s, NEG_INF)


def _fwd_impl(q, k, v, causal, scale, q_offset, block_k):
    B, Sq, H, D = q.shape
    Dv = v.shape[-1]
    qg, kb, vb, nk, bk, Sk, scale = _prep(q, k, v, scale, block_k)
    K, G = kb.shape[3], H // kb.shape[3]
    qpos = jnp.arange(Sq) + q_offset

    def step(carry, ik):
        m_prev, l_prev, acc = carry
        kpos = ik * bk + jnp.arange(bk)
        s = _scores(qg, kb[:, ik], kpos, qpos, Sk, causal, scale)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), vb[:, ik],
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, K, G, Dv), jnp.float32)
    inner_unroll = nk if os.environ.get('REPRO_UNROLL_INNER') else 1
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), jnp.arange(nk),
                                  unroll=inner_unroll)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(B, Sq, H, Dv).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _vjp_fwd(q, k, v, causal, scale, q_offset, block_k):
    out, lse = _fwd_impl(q, k, v, causal, scale, q_offset, block_k)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, scale, q_offset, block_k, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Dv = v.shape[-1]
    qg, kb, vb, nk, bk, Sk, scale_v = _prep(q, k, v, scale, block_k)
    K, G = kb.shape[3], H // kb.shape[3]
    qpos = jnp.arange(Sq) + q_offset
    do = dout.reshape(B, Sq, K, G, Dv)
    of = out.reshape(B, Sq, K, G, Dv)
    Dsum = jnp.sum(do.astype(jnp.float32) * of.astype(jnp.float32), axis=-1)

    def step(dq, ik):
        kpos = ik * bk + jnp.arange(bk)
        s = _scores(qg, kb[:, ik], kpos, qpos, Sk, causal, scale_v)
        p = jnp.exp(s - lse[..., None])                    # fp32 [B,Sq,K,G,bk]
        dv_b = jnp.einsum("bqkgs,bqkgd->bskd", p.astype(q.dtype), do,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,bskd->bqkgs", do, vb[:, ik],
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - Dsum[..., None])).astype(q.dtype)  # dL/d(s/scale-part)
        dq = dq + jnp.einsum("bqkgs,bskd->bqkgd", ds, kb[:, ik],
                             preferred_element_type=jnp.float32)
        dk_b = jnp.einsum("bqkgs,bqkgd->bskd", ds, qg,
                          preferred_element_type=jnp.float32)
        return dq, (dk_b, dv_b)

    dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    inner_unroll = nk if os.environ.get('REPRO_UNROLL_INNER') else 1
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(step, dq0, jnp.arange(nk),
                                              unroll=inner_unroll)
    dq = (dq * scale_v).reshape(B, Sq, H, D).astype(q.dtype)
    dk = (jnp.moveaxis(dk_blocks, 0, 1).reshape(B, nk * bk, K, D)[:, :Sk]
          * scale_v).astype(k.dtype)
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(B, nk * bk, K, Dv)[:, :Sk] \
        .astype(v.dtype)
    return dq, dk, dv


blockwise_attention.defvjp(_vjp_fwd, _vjp_bwd)
