"""Pure-jnp oracles for every Pallas kernel.

These are the *reference* implementations:
  * used as the compute path on non-TPU backends (this container),
  * used as the allclose oracle for the Pallas kernels (interpret=True),
  * written for clarity and numerical robustness (fp32 softmax/state).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_ref(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, K, D]
    v: jax.Array,  # [B, Sk, K, D]
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Grouped-query attention, fp32 softmax. Returns [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,       # [B, H, D]
    k: jax.Array,       # [B, Sk, K, D]
    v: jax.Array,       # [B, Sk, K, Dv]
    kv_len: jax.Array,  # [B] int32 — position p attended iff p < kv_len
    *,
    scale: float | None = None,
) -> jax.Array:
    """Single-token (Sq=1) GQA decode attention over a ragged KV cache.

    fp32 softmax; matches ``kernels/decode_attention.py``.  Every slot must
    have ``kv_len >= 1`` (an all-masked row would softmax to NaN).
    Returns [B, H, Dv]."""
    B, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    mask = jnp.arange(Sk)[None, :] < kv_len[:, None]          # [B, Sk]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, v.shape[-1]).astype(q.dtype)


def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialise a slot-major view of a paged pool.

    pool: [num_pages, page_size, ...]; page_table: [B, W] int32 (physical
    page backing each slot's logical page).  Returns [B, W*page_size, ...]
    where row ``j`` of slot ``b`` is token position ``j`` — the dense
    layout the non-paged reference kernels expect.  Rows past a slot's
    live length are stale pool contents; callers mask them by kv_len.
    Unmapped table entries hold an out-of-range sentinel — clamp instead
    of jnp.take's default NaN fill (0 * NaN would poison the masked
    matmul rows)."""
    B, W = page_table.shape
    pt = jnp.minimum(page_table, pool.shape[0] - 1)
    g = jnp.take(pool, pt, axis=0)                  # [B, W, ps, ...]
    return g.reshape(B, W * pool.shape[1], *pool.shape[2:])


def decode_attention_paged_ref(
    q: jax.Array,           # [B, H, D]
    k_pool: jax.Array,      # [P, ps, K, D]
    v_pool: jax.Array,      # [P, ps, K, Dv]
    page_table: jax.Array,  # [B, W] int32
    kv_len: jax.Array,      # [B] int32
    *,
    scale: float | None = None,
) -> jax.Array:
    """Paged Sq=1 decode attention: gather the slot's pages into a dense
    [B, W*ps, ...] view, then run the ragged dense reference.  Matches
    ``kernels/decode_attention.py::decode_attention_paged``.
    Returns [B, H, Dv]."""
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    return decode_attention_ref(q, k, v, kv_len, scale=scale)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — chunked reference
# ---------------------------------------------------------------------------
def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} x[..., t].

    x: [..., L] -> [..., L, L] lower-triangular cumulative sums.
    """
    L = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    out = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked_ref(
    x: jax.Array,   # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]   (already softplus'ed, > 0)
    A: jax.Array,   # [H]         (negative)
    Bm: jax.Array,  # [B, S, G, N]
    Cm: jax.Array,  # [B, S, G, N]
    *,
    chunk: int = 64,
    h0: jax.Array | None = None,  # [B, H, P, N] initial state
    return_final_state: bool = False,
):
    """Chunked SSD: y_t = C_t · h_t,  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.

    Heads H are grouped into G B/C groups (H % G == 0).
    Computation in fp32; output cast back to x.dtype.
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt=0 padding is state-neutral (decay 1, zero input contribution)
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        x, dt, Bm, Cm = zpad(x), zpad(dt), zpad(Bm), zpad(Cm)
        S_orig, S = S, S + pad
    nc = S // chunk
    rep = H // G

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    Bh = jnp.repeat(Bf, rep, axis=3)  # [B, nc, L, H, N]
    Ch = jnp.repeat(Cf, rep, axis=3)

    dA = dtf * A[None, None, None, :]              # [B, nc, L, H]
    dAc = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum
    # --- intra-chunk (quadratic within chunk) ---
    Lmat = jnp.exp(_segsum(jnp.swapaxes(dA, 2, 3)))  # [B, nc, H, L, L]
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh) * Lmat
    scores = scores * dtf.transpose(0, 1, 3, 2)[:, :, :, None, :]  # dt_s
    y_intra = jnp.einsum("bchls,bcshp->bclhp", scores, xf)

    # --- chunk states ---
    decay_to_end = jnp.exp(dAc[:, :, -1:, :] - dAc)          # [B, nc, L, H]
    Sc = jnp.einsum(
        "bclhn,bclh,bclhp->bchnp", Bh, decay_to_end * dtf, xf
    )  # [B, nc, H, N, P]

    # --- inter-chunk recurrence over nc chunks ---
    chunk_decay = jnp.exp(dAc[:, :, -1, :])  # [B, nc, H]
    h0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if h0 is None
          else jnp.swapaxes(h0.astype(jnp.float32), -1, -2))  # ->[B,H,N,P]

    def step(h, inp):
        dec, s = inp  # dec [B,H], s [B,H,N,P]
        h_new = h * dec[..., None, None] + s
        return h_new, h

    hT, h_prev = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(Sc, 1, 0)),
        unroll=nc if os.environ.get("REPRO_UNROLL_INNER") else 1,
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B, nc, H, N, P] state entering chunk
    y_inter = jnp.einsum(
        "bclhn,bchnp->bclhp", Ch * jnp.exp(dAc)[..., None], h_prev
    )
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    if pad:
        y = y[:, :S_orig]
    if return_final_state:
        return y.astype(x.dtype), jnp.swapaxes(hT, -1, -2)  # [B,H,P,N]
    return y.astype(x.dtype)


def ssd_sequential_ref(x, dt, A, Bm, Cm, h0=None):
    """O(S) sequential oracle (the definition). Returns (y, h_final).

    h: [B, H, P, N];  y_t = einsum(C_t, h_t)."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, t):
        dA = jnp.exp(dtf[:, t] * A[None, :])  # [B, H]
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dtf[:, t], Bh[:, t], xf[:, t])
        h = h * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t])
        return h, y

    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)  # [B, S, H, P]
    return y.astype(x.dtype), hT


def ssd_decode_step_ref(x, dt, A, Bm, Cm, h):
    """Single-token SSD update. x: [B,H,P], dt: [B,H], Bm/Cm: [B,G,N],
    h: [B,H,P,N] -> (y [B,H,P], h')."""
    G = Bm.shape[1]
    H = x.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])
    h_new = h * dA[..., None, None] + jnp.einsum("bh,bhn,bhp->bhpn", dtf, Bh, xf)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    return y.astype(x.dtype), h_new
