"""Compute-engine abstraction (paper: "To adapt to a given cloud platform,
one needs to merely provide an extension class with methods to create,
terminate and list compute instances").

Engines shipped:
  * LocalEngine  — real OS processes on this machine (the paper's local
    engine; doubles as the cloud simulation for development).
  * SimEngine    — deterministic virtual-clock simulator with failure
    injection (core/sim.py) used by tests/benchmarks.
  * GCEEngine    — Google Compute Engine via the gcloud CLI (the paper's
    proof-of-concept platform; builds the exact commands, executes them only
    if gcloud is available).
  * TPUPodEngine — TPU pod slices via queued resources (same contract; the
    create/list/delete verbs map onto `gcloud compute tpus queued-resources`).
"""
from __future__ import annotations

import contextlib
import multiprocessing as mp
import os
import shutil
import signal
import subprocess
import time
import warnings
from dataclasses import dataclass

from repro.core import transport


class RateLimited(Exception):
    """Instance creation rejected — caller must back off (paper: exponential
    delays between creation attempts)."""


class EngineUnavailable(Exception):
    pass


@dataclass
class PendingInstance:
    name: str
    kind: str                      # 'client' | 'backup'
    created_at: float
    primary_side: transport.Endpoint | None = None   # server-side endpoint
    backup_side: transport.Endpoint | None = None
    payload: object = None


class AbstractEngine:
    """Creation is asynchronous: the engine starts the instance; the
    instance handshakes with the primary server on the engine's handshake
    channel.  The server polls ``pending`` for endpoint records."""

    def now(self) -> float:
        raise NotImplementedError

    def create_instance(self, kind: str, name: str, payload=None) -> None:
        raise NotImplementedError

    def terminate_instance(self, name: str) -> None:
        raise NotImplementedError

    def list_instances(self) -> list:
        raise NotImplementedError

    # --- instance-kind registry -------------------------------------
    # Engines record the kind passed to create_instance so protocol code
    # (e.g. takeover's dangling-instance cleanup) never has to infer an
    # instance's role from its name.
    def instance_kind(self, name: str) -> str | None:
        return getattr(self, "_kinds", {}).get(name)

    # --- cost accounting ---------------------------------------------
    # ``billing_records()`` yields (name, kind, rate, start, end|None)
    # tuples the server's CostMeter syncs from; ``cost_rate`` is the
    # $/instance-second of one instance of ``kind``.  The base engine
    # bills nothing; concrete engines override (exact virtual-clock
    # accounting on SimEngine, wall-clock proxies on LocalEngine/GCE).
    def billing_records(self) -> list:
        return []

    def cost_rate(self, kind: str) -> float:
        return 1.0

    # --- lifecycle ---------------------------------------------------
    # Every engine is a context manager: ``with engines.make(...) as e``
    # guarantees instances/processes are reaped even when an exception
    # fires between create_instance and an explicit shutdown() call.
    def shutdown(self) -> None:
        """Release engine resources.  Idempotent; base engine holds none."""

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.shutdown()
        return False

    # server-side attach: engines own the handshake channel + endpoint books
    handshake_recv: transport.Channel
    pending: dict

    def primary_endpoints(self, name: str) -> transport.Endpoint:
        """Server-side endpoint of an instance's primary queues (used by a
        backup at takeover to send SWAP_QUEUES — the queues are globally
        addressable, as with SyncManager registration in the paper)."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Local engine: real processes (no backup server, as in the paper)
# ---------------------------------------------------------------------------
def _client_process_main(name, primary_send, primary_recv, handshake_q,
                         n_workers):
    from repro.core.client import Client
    from repro.core.workerpool import ProcessWorkerPool

    # own process group: the engine can reap this client *and* the worker
    # processes it spawned with one killpg, even after a hard error path
    with contextlib.suppress(OSError):
        os.setpgrp()
    chan = transport.MPChannel(primary_send, primary_recv)
    hs = transport.MPChannel(handshake_q, handshake_q)
    client = Client(name, chan, backup_channel=None,
                    pool=ProcessWorkerPool(n_workers), clock=time.time,
                    handshake=hs)
    client.run()


class LocalEngine(AbstractEngine):
    """Paper's local engine: each "instance" is a local process using
    ``n_workers_per_client`` worker processes (all CPUs by default)."""

    def __init__(self, n_workers_per_client: int | None = None):
        self._mgr = mp.Manager()
        self._procs: dict[str, mp.Process] = {}
        self.pending: dict[str, PendingInstance] = {}
        self._hq = self._mgr.Queue()
        self.handshake_recv = transport.MPChannel(self._hq, self._hq)
        self.n_workers = n_workers_per_client or max(1, mp.cpu_count())
        self._kinds: dict[str, str] = {}
        self._billing: dict[str, list] = {}   # name -> [kind, rate, t0, t1]

    def now(self) -> float:
        return time.time()

    def create_instance(self, kind, name, payload=None):
        if kind != "client":
            raise EngineUnavailable("LocalEngine runs without a backup server")
        if self._mgr is None:
            raise EngineUnavailable("LocalEngine already shut down")
        q_c2s, q_s2c = self._mgr.Queue(), self._mgr.Queue()
        server_side = transport.MPChannel(q_s2c, q_c2s)  # send s->c, recv c->s
        proc = mp.Process(
            target=_client_process_main,
            args=(name, q_c2s, q_s2c, self._hq, self.n_workers),
            daemon=False)  # clients spawn worker processes (no daemon)
        proc.start()
        self._procs[name] = proc
        self._kinds[name] = kind
        self._billing[name] = [kind, self.cost_rate(kind), self.now(), None]
        self.pending[name] = PendingInstance(
            name, kind, self.now(), primary_side=server_side)

    @staticmethod
    def _kill_group(p: mp.Process, sig) -> bool:
        """Signal the client's whole process group (client + its worker
        processes — the child called setpgrp, so pgid == its pid)."""
        try:
            os.killpg(p.pid, sig)
            return True
        except (ProcessLookupError, PermissionError, OSError):
            return False

    def terminate_instance(self, name):
        p = self._procs.pop(name, None)
        if p is not None:
            if p.is_alive():
                if not self._kill_group(p, signal.SIGTERM):
                    p.terminate()
                p.join(timeout=5)
            if p.is_alive():          # stuck past SIGTERM: escalate
                if not self._kill_group(p, signal.SIGKILL):
                    p.kill()
                p.join(timeout=5)
            else:
                # the client may have died on its own (crash/OOM),
                # orphaning daemon workers in its process group — reap
                # the group regardless (no-op if it is already gone)
                self._kill_group(p, signal.SIGKILL)
        self.pending.pop(name, None)
        rec = self._billing.get(name)
        if rec is not None and rec[3] is None:
            rec[3] = self.now()

    def billing_records(self):
        """Wall-clock proxy billing: one cost unit per instance-second."""
        return [(name, kind, rate, t0, t1)
                for name, (kind, rate, t0, t1) in self._billing.items()]

    def list_instances(self):
        return list(self._procs)

    def shutdown(self):
        for name in list(self._procs):
            self.terminate_instance(name)
        if self._mgr is not None:
            self._mgr.shutdown()
            self._mgr = None


# ---------------------------------------------------------------------------
# GCE engine (the paper's proof of concept) — gcloud CLI contract
# ---------------------------------------------------------------------------
class GCEEngine(AbstractEngine):
    """Command contract follows the paper's config keys.  Execution requires
    the gcloud CLI + network; command *construction* is covered by tests
    against a fake gcloud shim."""

    def __init__(self, config: dict, runner=None):
        required = {"prefix", "project", "zone", "server_image",
                    "client_image", "root_folder", "project_folder"}
        missing = required - set(config)
        if missing:
            raise ValueError(f"GCE config missing keys: {sorted(missing)}")
        self.config = dict(config)
        self._run = runner or self._default_runner
        self.pending: dict[str, PendingInstance] = {}
        self._kinds: dict[str, str] = {}
        self._billing: dict[str, list] = {}   # name -> [kind, rate, t0, t1]
        self._rate_fallback_warned: set[str] = set()

    def now(self) -> float:
        return time.time()

    def cost_rate(self, kind: str) -> float:
        """$/instance-second from the ``cost_rates`` config key (scalar or
        kind->rate mapping).  An unconfigured kind falls back to 1.0 with
        a once-per-kind warning — a silent 1.0 would make real-run cost
        summaries quietly wrong."""
        rates = self.config.get("cost_rates")
        if isinstance(rates, dict):
            if kind in rates:
                return float(rates[kind])
        elif rates is not None:
            return float(rates)
        if kind not in self._rate_fallback_warned:
            self._rate_fallback_warned.add(kind)
            warnings.warn(
                f"{type(self).__name__}: no cost rate configured for "
                f"instance kind {kind!r}; falling back to 1.0 "
                f"$/instance-second — set config['cost_rates'] "
                f"(scalar or {{kind: rate}}) for true cost summaries",
                stacklevel=2)
        return 1.0

    def billing_records(self):
        return [(name, kind, rate, t0, t1)
                for name, (kind, rate, t0, t1) in self._billing.items()]

    @staticmethod
    def _default_runner(cmd: list[str]) -> str:
        if shutil.which(cmd[0]) is None:
            raise EngineUnavailable(f"{cmd[0]} not on PATH")
        return subprocess.run(cmd, check=True, capture_output=True,
                              text=True).stdout

    def _instance_name(self, name: str) -> str:
        return f"{self.config['prefix']}-{name}"

    def create_command(self, kind: str, name: str) -> list[str]:
        image = self.config["server_image"] if kind == "backup" \
            else self.config["client_image"]
        return [
            "gcloud", "compute", "instances", "create",
            self._instance_name(name),
            f"--project={self.config['project']}",
            f"--zone={self.config['zone']}",
            f"--source-machine-image={image}",
        ]

    def delete_command(self, name: str) -> list[str]:
        return [
            "gcloud", "compute", "instances", "delete",
            self._instance_name(name), "--quiet",
            f"--project={self.config['project']}",
            f"--zone={self.config['zone']}",
        ]

    def list_command(self) -> list[str]:
        return [
            "gcloud", "compute", "instances", "list",
            f"--project={self.config['project']}",
            f"--filter=name~^{self.config['prefix']}-",
            "--format=value(name)",
        ]

    def create_instance(self, kind, name, payload=None):
        self._run(self.create_command(kind, name))
        self._kinds[name] = kind
        self._billing[name] = [kind, self.cost_rate(kind), self.now(), None]
        self.pending[name] = PendingInstance(name, kind, self.now())

    def terminate_instance(self, name):
        self._run(self.delete_command(name))
        self.pending.pop(name, None)
        rec = self._billing.get(name)
        if rec is not None and rec[3] is None:
            rec[3] = self.now()

    def list_instances(self):
        out = self._run(self.list_command())
        prefix = self.config["prefix"] + "-"
        return [line[len(prefix):] for line in out.splitlines() if line]

    def shutdown(self):
        """Best-effort: delete every instance this engine created whose
        billing interval is still open (real VMs keep billing after the
        driver process dies — the context-manager exit is the backstop)."""
        for name, rec in list(self._billing.items()):
            if rec[3] is None:
                try:
                    self.terminate_instance(name)
                except Exception as e:   # noqa: BLE001 — best-effort reap
                    warnings.warn(f"shutdown: could not delete instance "
                                  f"{name!r}: {e}", stacklevel=2)


class TPUPodEngine(GCEEngine):
    """TPU pod slices via queued resources: same create/terminate/list
    contract, different verbs.  ``accelerator_type`` e.g. 'v5litepod-256'
    — one ExpoCloud 'instance' == one pod slice == one mesh job."""

    def __init__(self, config: dict, runner=None):
        config = dict(config)
        config.setdefault("accelerator_type", "v5litepod-256")
        config.setdefault("runtime_version", "v2-alpha-tpuv5-lite")
        super().__init__(config, runner=runner)

    def create_command(self, kind, name):
        return [
            "gcloud", "compute", "tpus", "queued-resources", "create",
            self._instance_name(name),
            f"--project={self.config['project']}",
            f"--zone={self.config['zone']}",
            f"--accelerator-type={self.config['accelerator_type']}",
            f"--runtime-version={self.config['runtime_version']}",
            f"--node-id={self._instance_name(name)}",
        ]

    def delete_command(self, name):
        return [
            "gcloud", "compute", "tpus", "queued-resources", "delete",
            self._instance_name(name), "--quiet", "--force",
            f"--project={self.config['project']}",
            f"--zone={self.config['zone']}",
        ]

    def list_command(self):
        return [
            "gcloud", "compute", "tpus", "queued-resources", "list",
            f"--project={self.config['project']}",
            f"--zone={self.config['zone']}",
            f"--filter=name~{self.config['prefix']}-",
            "--format=value(name)",
        ]
