"""Task hardness — the paper's pruning data structures.

A task's hardness is a tuple of parameter values that correlate with
execution time.  The default comparison (paper, AbstractTask): T1 is as
hard or harder than T2 iff *all* hardness parameters of T1 are >= the
corresponding parameters of T2 — a componentwise partial order.

``MinHardSet`` is the paper's ``min_hard`` list: the set of hardnesses of
timed-out tasks, "kept small by only storing the minimal elements" — i.e. a
Pareto-minimal antichain.  A task is disqualified iff its hardness
dominates (>=) any stored element.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardness:
    values: tuple

    def geq(self, other: Hardness) -> bool:
        """self as hard or harder than other (componentwise >=).

        Raises ValueError on arity mismatch — an ``assert`` would vanish
        under ``python -O`` and silently compare truncated tuples."""
        if len(self.values) != len(other.values):
            raise ValueError(
                f"incomparable hardness arities: {len(self.values)} "
                f"vs {len(other.values)}")
        return all(a >= b
                   for a, b in zip(self.values, other.values, strict=True))

    def __le__(self, other):
        return other.geq(self)

    def __ge__(self, other):
        return self.geq(other)


class MinHardSet:
    """Pareto-minimal antichain of timed-out hardnesses."""

    def __init__(self):
        self._items: list[Hardness] = []

    def __len__(self):
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    def add(self, h: Hardness) -> bool:
        """Insert h; keep only minimal elements. Returns True if h was
        retained (i.e. it was not already dominated-from-below)."""
        for m in self._items:
            if h.geq(m):        # an existing element is already <= h
                return False
        self._items = [m for m in self._items if not m.geq(h)]
        self._items.append(h)
        return True

    def disqualifies(self, h: Hardness) -> bool:
        """True iff h is as hard or harder than some timed-out hardness."""
        return any(h.geq(m) for m in self._items)

    def snapshot(self) -> list[tuple]:
        return [m.values for m in self._items]

    def restore(self, values: list[tuple]):
        self._items = [Hardness(tuple(v)) for v in values]
