"""Task hardness — the paper's pruning data structures.

A task's hardness is a tuple of parameter values that correlate with
execution time.  The default comparison (paper, AbstractTask): T1 is as
hard or harder than T2 iff *all* hardness parameters of T1 are >= the
corresponding parameters of T2 — a componentwise partial order.

``MinHardSet`` is the paper's ``min_hard`` list: the set of hardnesses of
timed-out tasks, "kept small by only storing the minimal elements" — i.e. a
Pareto-minimal antichain.  A task is disqualified iff its hardness
dominates (>=) any stored element.
"""
from __future__ import annotations

from bisect import bisect_left, bisect_right, insort
from dataclasses import dataclass


@dataclass(frozen=True)
class Hardness:
    values: tuple

    def geq(self, other: Hardness) -> bool:
        """self as hard or harder than other (componentwise >=).

        Raises ValueError on arity mismatch — an ``assert`` would vanish
        under ``python -O`` and silently compare truncated tuples."""
        if len(self.values) != len(other.values):
            raise ValueError(
                f"incomparable hardness arities: {len(self.values)} "
                f"vs {len(other.values)}")
        return all(a >= b
                   for a, b in zip(self.values, other.values, strict=True))

    def __le__(self, other):
        return other.geq(self)

    def __ge__(self, other):
        return self.geq(other)


# sentinel sort key: greater than every real insertion key, so
# bisect over (value, key) pairs can bracket "all entries with this value"
_KEY_MAX = float("inf")


class MinHardSet:
    """Pareto-minimal antichain of timed-out hardnesses.

    Beyond the ordered ``_items`` list (whose insertion order is part of
    the snapshot format and must stay byte-identical to the historical
    naive implementation), a *dominance index* of per-dimension sorted
    projections answers ``disqualifies``/``add`` without scanning the
    whole frontier: dimension ``d`` holds a sorted list of
    ``(value, key)`` pairs, so the stored elements with ``m[d] <= h[d]``
    form a prefix found by bisection.  ``h`` dominates a stored element
    only if every dimension's prefix is non-empty, and only the smallest
    prefix's candidates need the full componentwise check — on a frontier
    of n elements in d dimensions a query costs O(d log n + c) for c
    surviving candidates instead of O(n d).
    """

    def __init__(self):
        self._items: list[Hardness] = []
        self._rebuild_index()

    def __len__(self):
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    # -- dominance index -------------------------------------------------
    def _rebuild_index(self):
        # _keys runs parallel to _items; _by_key resolves a projection
        # entry back to its element; _proj[d] is the sorted d-th projection
        self._keys = list(range(len(self._items)))
        self._next_key = len(self._items)
        self._by_key = dict(zip(self._keys, self._items))
        if self._items:
            self._proj = [[] for _ in self._items[0].values]
            for key, m in zip(self._keys, self._items):
                for d, v in enumerate(m.values):
                    self._proj[d].append((v, key))
            for col in self._proj:
                col.sort()
        else:
            self._proj = None

    def _index_append(self, h: Hardness):
        key = self._next_key
        self._next_key += 1
        self._items.append(h)
        self._keys.append(key)
        self._by_key[key] = h
        if self._proj is None:
            self._proj = [[] for _ in h.values]
        for d, v in enumerate(h.values):
            insort(self._proj[d], (v, key))

    def _index_remove(self, doomed: set):
        for key in doomed:
            m = self._by_key.pop(key)
            for d, v in enumerate(m.values):
                col = self._proj[d]
                del col[bisect_left(col, (v, key))]
        keep = [i for i, k in enumerate(self._keys) if k not in doomed]
        self._items = [self._items[i] for i in keep]
        self._keys = [self._keys[i] for i in keep]
        if not self._items:
            self._proj = None

    def _check_arity(self, h: Hardness):
        if len(h.values) != len(self._proj):
            raise ValueError(
                f"incomparable hardness arities: {len(h.values)} "
                f"vs {len(self._proj)}")

    def _dominated_keys(self, h: Hardness) -> set:
        """Keys of stored elements m with m.geq(h) (to evict on add)."""
        hv = h.values
        best_d, best_n = 0, len(self._items) + 1
        for d, col in enumerate(self._proj):
            # suffix of entries with m[d] >= h[d]
            n = len(col) - bisect_left(col, (hv[d], -1))
            if n == 0:
                return set()
            if n < best_n:
                best_d, best_n = d, n
        col = self._proj[best_d]
        by_key = self._by_key
        return {key for _, key in col[len(col) - best_n:]
                if by_key[key].geq(h)}

    # -- public API (semantics identical to the naive list scan) ---------
    def add(self, h: Hardness) -> bool:
        """Insert h; keep only minimal elements. Returns True if h was
        retained (i.e. it was not already dominated-from-below)."""
        if self._items:
            if self.disqualifies(h):
                return False
            doomed = self._dominated_keys(h)
            if doomed:
                self._index_remove(doomed)
        self._index_append(h)
        return True

    def disqualifies(self, h: Hardness) -> bool:
        """True iff h is as hard or harder than some timed-out hardness."""
        if not self._items:
            return False
        self._check_arity(h)
        hv = h.values
        best_d, best_n = 0, len(self._items) + 1
        for d, col in enumerate(self._proj):
            # prefix of entries with m[d] <= h[d]
            n = bisect_right(col, (hv[d], _KEY_MAX))
            if n == 0:
                return False
            if n < best_n:
                best_d, best_n = d, n
        col = self._proj[best_d]
        by_key = self._by_key
        return any(h.geq(by_key[key]) for _, key in col[:best_n])

    def snapshot(self) -> list[tuple]:
        return [m.values for m in self._items]

    def restore(self, values: list[tuple]):
        self._items = [Hardness(tuple(v)) for v in values]
        self._rebuild_index()
