"""Worker pools: one worker per task (paper: "a client creates and manages
worker processes; each worker is responsible for executing a single task").

``ProcessWorkerPool`` uses real OS processes (LocalEngine / cloud clients);
``SimWorkerPool`` executes tasks on the virtual clock using each task's
``sim_duration`` attribute (deterministic tests/benchmarks).
"""
from __future__ import annotations

import multiprocessing as mp
import queue as _queue
import time
import traceback


class WorkerEvent:
    STARTED = "WORKER_STARTED"
    DONE = "WORKER_DONE"
    ERROR = "WORKER_ERROR"

    __slots__ = ("kind", "task_id", "payload")

    def __init__(self, kind, task_id, payload=None):
        self.kind = kind
        self.task_id = task_id
        self.payload = payload


def _worker_main(task_id, task, q):
    q.put(WorkerEvent(WorkerEvent.STARTED, task_id))
    try:
        result = task.run()
        q.put(WorkerEvent(WorkerEvent.DONE, task_id, result))
    except BaseException as e:  # noqa: BLE001 — reported upstream
        q.put(WorkerEvent(WorkerEvent.ERROR, task_id,
                          f"{type(e).__name__}: {e}\n{traceback.format_exc()}"))


class ProcessWorkerPool:
    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._q = mp.Queue()
        self._procs: dict[int, mp.Process] = {}
        self._started: dict[int, float] = {}

    def idle(self) -> int:
        return self.n_workers - len(self._procs)

    def running(self) -> dict[int, float]:
        return dict(self._started)

    def running_ref(self) -> dict[int, float]:
        """Internal start-time map, NOT copied — read-only view for hot
        paths that only scan (the client's per-step timeout sweep)."""
        return self._started

    def start(self, task_id: int, task) -> None:
        p = mp.Process(target=_worker_main, args=(task_id, task, self._q),
                       daemon=True)
        p.start()
        self._procs[task_id] = p
        self._started[task_id] = time.time()

    def poll(self) -> list[WorkerEvent]:
        events = []
        while True:
            try:
                ev = self._q.get_nowait()
            except _queue.Empty:
                break
            events.append(ev)
            if ev.kind in (WorkerEvent.DONE, WorkerEvent.ERROR):
                self._reap(ev.task_id)
        # reap processes that died without reporting (hard crash)
        for tid, p in list(self._procs.items()):
            if not p.is_alive():
                p.join(timeout=1)
                self._reap(tid)
                events.append(WorkerEvent(WorkerEvent.ERROR, tid,
                                          "worker died (no report)"))
        return events

    def terminate(self, task_id: int) -> None:
        p = self._procs.get(task_id)
        if p is not None and p.is_alive():
            p.terminate()
            p.join(timeout=5)
        self._reap(task_id)

    def _reap(self, task_id):
        self._procs.pop(task_id, None)
        self._started.pop(task_id, None)

    def shutdown(self):
        for tid in list(self._procs):
            self.terminate(tid)


class SimWorkerPool:
    """Virtual-clock pool: each task must carry ``sim_duration`` (seconds of
    virtual time); completion fires when the clock passes start+duration.

    ``notify`` (optional, set by the discrete-event engine) is called with
    the timestamp of every scheduled completion so the owning client is
    woken exactly then instead of being polled every ``dt``.
    ``runtime_fn(task_id, default)`` (optional) resolves the virtual
    duration — the engine's trace record/replay hook."""

    def __init__(self, n_workers: int, clock, notify=None, runtime_fn=None):
        self.n_workers = n_workers
        self._clock = clock
        self._running: dict[int, tuple] = {}   # id -> (task, start, end)
        self._running_view: dict | None = None  # lazy running_ref() cache
        self._next_end: float | None = None     # lazy min-end cache
        self._pending_started: list[int] = []
        self.notify = notify
        self.runtime_fn = runtime_fn

    def idle(self) -> int:
        return self.n_workers - len(self._running)

    def running(self) -> dict[int, float]:
        return {tid: t0 for tid, (_, t0, _) in self._running.items()}

    def running_ref(self):
        """Read-only {tid: t0} view for hot paths.  Built lazily and
        invalidated on every start/terminate/poll-completion — the
        client's per-step sweeps would otherwise rebuild the dict three
        times per wake."""
        if self._running_view is None:
            self._running_view = {tid: t0 for tid, (_, t0, _)
                                  in self._running.items()}
        return self._running_view

    def next_completion(self) -> float | None:
        """Earliest scheduled completion time, or None when idle (used by
        the client's next_wake hint and poll()'s nothing-due fast path).
        Cached; invalidated whenever the running set changes."""
        if self._next_end is None and self._running:
            self._next_end = min(end for _, _, end in self._running.values())
        return self._next_end

    def start(self, task_id: int, task) -> None:
        now = self._clock.now()
        dur = getattr(task, "sim_duration", 1.0)
        if self.runtime_fn is not None:
            dur = self.runtime_fn(task_id, dur)
        self._running[task_id] = (task, now, now + dur)
        self._running_view = None
        if self._next_end is not None and now + dur < self._next_end:
            self._next_end = now + dur
        self._pending_started.append(task_id)
        if self.notify is not None:
            # completion wake only: the client drains STARTED events
            # synchronously via drain_started() in the same step that
            # started the workers, so no extra wake is needed for them
            self.notify(now + dur)

    def drain_started(self) -> list[int]:
        """Pop and return tids whose STARTED event is pending — called by
        the client right after starting workers so the lifecycle LOG goes
        out in the same step instead of one wake later."""
        out = self._pending_started
        self._pending_started = []
        return out

    def poll(self) -> list[WorkerEvent]:
        now = self._clock.now()
        if not self._pending_started:
            # nothing-due fast path: most wakes deliver messages, not
            # completions — skip the running-set scan entirely
            nxt = self.next_completion()
            if nxt is None or now < nxt:
                return []
        events = [WorkerEvent(WorkerEvent.STARTED, tid)
                  for tid in self._pending_started]
        self._pending_started.clear()
        completed = False
        for tid, (task, _t0, t_end) in list(self._running.items()):
            if now >= t_end:
                completed = True
                del self._running[tid]
                self._running_view = None
                try:
                    result = task.run()
                except BaseException as e:  # noqa: BLE001
                    events.append(WorkerEvent(WorkerEvent.ERROR, tid, str(e)))
                else:
                    events.append(WorkerEvent(WorkerEvent.DONE, tid, result))
        if completed:
            self._next_end = None
        return events

    def terminate(self, task_id: int) -> None:
        self._running.pop(task_id, None)
        self._running_view = None
        self._next_end = None

    def shutdown(self):
        self._running.clear()
        self._running_view = None
        self._next_end = None
