"""Worker pools: one worker per task (paper: "a client creates and manages
worker processes; each worker is responsible for executing a single task").

``ProcessWorkerPool`` uses real OS processes (LocalEngine / cloud clients);
``SimWorkerPool`` executes tasks on the virtual clock using each task's
``sim_duration`` attribute (deterministic tests/benchmarks).
"""
from __future__ import annotations

import multiprocessing as mp
import queue as _queue
import time
import traceback


class WorkerEvent:
    STARTED = "WORKER_STARTED"
    DONE = "WORKER_DONE"
    ERROR = "WORKER_ERROR"

    def __init__(self, kind, task_id, payload=None):
        self.kind = kind
        self.task_id = task_id
        self.payload = payload


def _worker_main(task_id, task, q):
    q.put(WorkerEvent(WorkerEvent.STARTED, task_id))
    try:
        result = task.run()
        q.put(WorkerEvent(WorkerEvent.DONE, task_id, result))
    except BaseException as e:  # noqa: BLE001 — reported upstream
        q.put(WorkerEvent(WorkerEvent.ERROR, task_id,
                          f"{type(e).__name__}: {e}\n{traceback.format_exc()}"))


class ProcessWorkerPool:
    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._q = mp.Queue()
        self._procs: dict[int, mp.Process] = {}
        self._started: dict[int, float] = {}

    def idle(self) -> int:
        return self.n_workers - len(self._procs)

    def running(self) -> dict[int, float]:
        return dict(self._started)

    def start(self, task_id: int, task) -> None:
        p = mp.Process(target=_worker_main, args=(task_id, task, self._q),
                       daemon=True)
        p.start()
        self._procs[task_id] = p
        self._started[task_id] = time.time()

    def poll(self) -> list[WorkerEvent]:
        events = []
        while True:
            try:
                ev = self._q.get_nowait()
            except _queue.Empty:
                break
            events.append(ev)
            if ev.kind in (WorkerEvent.DONE, WorkerEvent.ERROR):
                self._reap(ev.task_id)
        # reap processes that died without reporting (hard crash)
        for tid, p in list(self._procs.items()):
            if not p.is_alive():
                p.join(timeout=1)
                self._reap(tid)
                events.append(WorkerEvent(WorkerEvent.ERROR, tid,
                                          "worker died (no report)"))
        return events

    def terminate(self, task_id: int) -> None:
        p = self._procs.get(task_id)
        if p is not None and p.is_alive():
            p.terminate()
            p.join(timeout=5)
        self._reap(task_id)

    def _reap(self, task_id):
        self._procs.pop(task_id, None)
        self._started.pop(task_id, None)

    def shutdown(self):
        for tid in list(self._procs):
            self.terminate(tid)


class SimWorkerPool:
    """Virtual-clock pool: each task must carry ``sim_duration`` (seconds of
    virtual time); completion fires when the clock passes start+duration.

    ``notify`` (optional, set by the discrete-event engine) is called with
    the timestamp of every scheduled completion so the owning client is
    woken exactly then instead of being polled every ``dt``.
    ``runtime_fn(task_id, default)`` (optional) resolves the virtual
    duration — the engine's trace record/replay hook."""

    def __init__(self, n_workers: int, clock, notify=None, runtime_fn=None):
        self.n_workers = n_workers
        self._clock = clock
        self._running: dict[int, tuple] = {}   # id -> (task, start, end)
        self._pending_started: list[int] = []
        self.notify = notify
        self.runtime_fn = runtime_fn

    def idle(self) -> int:
        return self.n_workers - len(self._running)

    def running(self) -> dict[int, float]:
        return {tid: t0 for tid, (_, t0, _) in self._running.items()}

    def next_completion(self) -> float | None:
        """Earliest scheduled completion time, or None when idle (used by
        the client's next_wake hint)."""
        if not self._running:
            return None
        return min(end for _, _, end in self._running.values())

    def start(self, task_id: int, task) -> None:
        now = self._clock.now()
        dur = getattr(task, "sim_duration", 1.0)
        if self.runtime_fn is not None:
            dur = self.runtime_fn(task_id, dur)
        self._running[task_id] = (task, now, now + dur)
        self._pending_started.append(task_id)
        if self.notify is not None:
            self.notify(now)            # emit STARTED promptly
            self.notify(now + dur)      # wake at completion

    def poll(self) -> list[WorkerEvent]:
        events = [WorkerEvent(WorkerEvent.STARTED, tid)
                  for tid in self._pending_started]
        self._pending_started.clear()
        now = self._clock.now()
        for tid, (task, _t0, t_end) in list(self._running.items()):
            if now >= t_end:
                del self._running[tid]
                try:
                    result = task.run()
                except BaseException as e:  # noqa: BLE001
                    events.append(WorkerEvent(WorkerEvent.ERROR, tid, str(e)))
                else:
                    events.append(WorkerEvent(WorkerEvent.DONE, tid, result))
        return events

    def terminate(self, task_id: int) -> None:
        self._running.pop(task_id, None)

    def shutdown(self):
        self._running.clear()
