"""The ML bridge: ExpoCloud tasks whose "parameter setting" is a cell of
the (architecture x input-shape x mesh x variant) exploration grid.

Each task runs ``repro.launch.dryrun`` in a fresh subprocess (own XLA
device-count env, isolated memory) with the cell's config, parses the JSON
record and returns the roofline terms.  Hardness is the static-analysis
tuple from configs.analysis (params, step FLOPs, cache bytes, seq, tokens)
plus chips and layer count — all monotone proxies for lower+compile cost —
so a timeout on one cell domino-prunes every cell that dominates it
(the paper's mechanism, applied to our own experiment).
"""
from __future__ import annotations

import contextlib
import json
import os
import signal
import subprocess
import sys
import tempfile

from repro.configs import get_config, get_shape
from repro.configs.analysis import hardness_tuple
from repro.configs.registry import segment_counts
from repro.core.task import AbstractTask

RESULT_TITLES = ("status", "dominant", "compute_s", "memory_s",
                 "collective_s", "useful_ratio", "roofline_frac",
                 "compile_s", "json_path")


class DryRunCellTask(AbstractTask):
    def __init__(self, arch: str, shape: str, mesh: str = "single",
                 seg_counts: tuple | None = None, variant: dict | None = None,
                 deadline: float = 1800.0, out_dir: str = "dryrun_results",
                 devices: int = 512, tag: str = "", mesh_shape=None,
                 mesh_axes=None):
        self.arch = arch
        self.shape = shape
        self.mesh = mesh                    # 'single' | 'multi'
        self.seg_counts = tuple(seg_counts) if seg_counts else None
        self.variant = dict(variant or {})
        self.deadline = deadline
        self.out_dir = out_dir
        self.devices = devices
        self.tag = tag
        # test-sized override (must fit `devices` host devices)
        self.mesh_shape = tuple(mesh_shape) if mesh_shape else None
        self.mesh_axes = tuple(mesh_axes) if mesh_axes else None

    # --- ExpoCloud interface -------------------------------------------
    def parameter_titles(self):
        return ("arch", "shape", "mesh", "probe", "variant", "id")

    def parameters(self):
        probe = "full" if self.seg_counts is None else \
            "L" + "-".join(map(str, self.seg_counts))
        vstr = ",".join(f"{k}={v}" for k, v in sorted(self.variant.items())) \
            or "base"
        return (self.arch, self.shape, self.mesh, probe, vstr, self.tag)

    def hardness_parameters(self):
        cfg = get_config(self.arch)
        shape = get_shape(self.shape)
        h = hardness_tuple(cfg, shape)
        chips = 512 if self.mesh == "multi" else 256
        full = sum(segment_counts(cfg))
        layers = sum(self.seg_counts) if self.seg_counts else full
        # scale the static tuple by the fraction of layers actually built
        frac = layers / full
        return tuple(int(x * frac) for x in h) + (chips,)

    def result_titles(self):
        return RESULT_TITLES

    def timeout(self):
        return self.deadline

    def group_parameter_titles(self):
        return ("arch", "shape", "mesh")

    # --- execution -------------------------------------------------------
    def _json_name(self) -> str:
        probe = "full" if self.seg_counts is None else \
            "L" + "-".join(map(str, self.seg_counts))
        v = "_".join(f"{k}-{val}" for k, val in sorted(self.variant.items()))
        v = ("_" + v) if v else ""
        return f"{self.arch}__{self.shape}__{self.mesh}__{probe}{v}.json"

    def run(self):
        os.makedirs(self.out_dir, exist_ok=True)
        json_path = os.path.join(self.out_dir, self._json_name())
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", self.arch, "--shape", self.shape,
               "--json", json_path]
        if self.mesh_shape is not None:
            cmd += ["--mesh-shape"] + [str(x) for x in self.mesh_shape]
            cmd += ["--mesh-axes"] + list(self.mesh_axes)
        elif self.mesh == "multi":
            cmd.append("--multi-pod")
        if self.seg_counts is not None:
            cmd += ["--seg-counts"] + [str(c) for c in self.seg_counts]
        if self.variant:
            cmd += ["--variant"] + [f"{k}={v}"
                                    for k, v in self.variant.items()]
        env = dict(os.environ)
        env["REPRO_DRYRUN_DEVICES"] = str(self.devices)
        env.setdefault("PYTHONPATH", "src")

        # run in its own process group so a worker-level kill reaps it
        proc = subprocess.Popen(cmd, env=env, start_new_session=True,
                                stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)

        def _kill(*_):
            with contextlib.suppress(ProcessLookupError):
                os.killpg(proc.pid, signal.SIGKILL)
            sys.exit(1)

        signal.signal(signal.SIGTERM, _kill)
        try:
            out, _ = proc.communicate(timeout=self.deadline + 120)
        except subprocess.TimeoutExpired:
            _kill()
        if proc.returncode != 0:
            tail = "\n".join(out.splitlines()[-15:]) if out else ""
            raise RuntimeError(
                f"dryrun failed rc={proc.returncode}:\n{tail}")
        with open(json_path) as f:
            rec = json.load(f)
        if rec.get("status") == "inapplicable":
            return ("inapplicable", "", 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                    json_path)
        roof = rec["roofline"]
        return ("ok", roof["dominant"], roof["compute_s"], roof["memory_s"],
                roof["collective_s"], roof["useful_ratio"],
                roof["roofline_fraction"], rec["compile_s"], json_path)


def probe_plans(arch: str) -> list[tuple]:
    """Unrolled probe seg-count combos for linear extrapolation: a base
    point and +1 along each segment."""
    cfg = get_config(arch)
    counts = segment_counts(cfg)
    base = tuple(min(c, 2) if len(counts) == 1 else (1 if i == 0 else 2)
                 for i, c in enumerate(counts))
    if cfg.hybrid_block:
        base = (1,)
    plans = [base]
    for i in range(len(counts)):
        bumped = list(base)
        bumped[i] += 1
        plans.append(tuple(bumped))
    return plans
