"""Pure scheduler core — a deterministic state machine over typed events.

The paper's primary-server loop is split in three here (motivated by
JobPruner's policy/mechanism separation and by Gent & Kotthoff's case for
deterministic replay on unreliable virtualized hardware):

  * **core** (this module): ``SchedulerCore`` owns the task table, the
    ``MinHardSet`` pruning antichain and the client bookkeeping.  It
    consumes typed events — ``ClientMessage``, ``ClientJoined``,
    ``ClientLost``, ``Tick`` — and emits typed effects — ``Send``,
    ``CreateInstance``, ``TerminateInstance``.  It imports **no**
    transport or engine code: the same event stream always produces the
    same effect stream and the same ``snapshot()``, which is what makes
    backup takeover "replay the forwarded stream into the same core".
  * **policies** (``repro.core.policy``): assignment order, fleet
    scaling and budget enforcement are strategy objects the core
    consults; they are rebuilt deterministically from the config.
  * **shell** (``repro.core.server``): feeds events from real channels
    and executes effects against a compute engine.

``snapshot()``/``restore()`` replace the old ad-hoc pickle blob with a
structured, complete state capture (including per-client assignment
tables and retry counters, which the old blob silently dropped).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any

from repro.core import policy as _policy
from repro.core.hardness import Hardness, MinHardSet
from repro.core.messages import Message, MsgType
from repro.core.results import EventLog

# task status values
PENDING, ASSIGNED, DONE, TIMED_OUT, PRUNED, FAILED_POOL = (
    "pending", "assigned", "done", "timed_out", "pruned", "failed_pool")


@dataclass
class ServerConfig:
    min_group_size: int = 0
    max_task_attempts: int = 3      # poison-task cap (beyond-paper)
    use_backup: bool = False
    max_clients: int = 4
    workers_hint: int = 1              # informational; pools size themselves
    health_update_limit: float = 10.0
    instance_max_non_active_time: float = 30.0
    create_backoff_init: float = 0.5
    create_backoff_max: float = 30.0
    health_interval: float = 1.0
    out_dir: str | None = None
    # policy layer (see repro.core.policy)
    assign_policy: str = "hardness"    # "hardness" | "backfill"
    assign_batch: int = 4              # batch size for "backfill"
    scale_policy: str = "fixed"        # "fixed" | "demand"
    idle_timeout_s: float = 5.0        # demand scale: idle-downscale cutoff
    budget_cap: float | None = None    # stop scaling when cap is threatened
    budget_reserve_s: float = 30.0     # projection horizon for the cap
    create_batch: int = 1              # max CreateInstance effects per tick
    #   (fleet-scale boot: one create per tick serializes a 10k fleet)
    name_prefix: str = ""              # instance-name namespace; sharded
    #   runs give each shard its own prefix so names are globally unique
    # partition hardening (see repro.core.policy.LivenessPolicy):
    partition_grace_s: float = 0.0     # extra liveness allowance while a
    #   client's link is reported partitioned (LinkLost) — a partitioned-
    #   but-alive client is not declared dead until limit + grace
    regrant_timeout_s: float = 6.0     # re-send an unacknowledged GRANT on
    #   the client's next request after this long (recovers grants lost to
    #   one-way server->client link loss; acked within ~2 RTT normally)


@dataclass
class ClientInfo:
    """Per-client record.  The core reads/writes everything except
    ``endpoint``, which the shell stores here for effect execution and
    which is deliberately excluded from snapshots."""

    name: str
    endpoint: Any
    last_health: float
    srv_seq: int = 0                    # per-client logical send counter
    last_client_seq: int = -1           # highest processed client msg seq
    assigned: dict = field(default_factory=dict)   # tid -> task
    capacity: int = 0                   # observed peak worker demand
    last_active: float = 0.0            # last task-lifecycle activity
    suspected_at: float | None = None   # LinkLost time (None = link fine)
    unacked: dict = field(default_factory=dict)    # tid -> grant time, not
    #   yet acknowledged (client's "granted"/"started" LOG or RESULT)


# ---------------------------------------------------------------------------
# typed events (inputs)
# ---------------------------------------------------------------------------
@dataclass
class ClientMessage:
    msg: Message
    now: float


@dataclass
class ClientJoined:
    name: str
    now: float


@dataclass
class ClientLost:
    name: str
    now: float
    reassign: bool = True


@dataclass
class LinkLost:
    """The transport reports the link to a client as (partially) down —
    the client may be partitioned-but-alive, so liveness gets
    ``partition_grace_s`` more allowance before declaring it dead."""

    name: str
    now: float


@dataclass
class LinkHealed:
    """The client's link recovered; normal liveness allowance resumes."""

    name: str
    now: float


@dataclass
class Tick:
    """Periodic decision point.  Everything the core may not observe
    directly (engine pending counts, shell backoff state, metered cost)
    arrives as event payload, so replaying ticks is deterministic.

    ``pending_instances`` counts every booting instance (the paper's
    fixed-fleet gate counts backups too); ``pending_clients`` counts
    only client-kind instances (worker capacity, used by demand
    scaling)."""

    now: float
    pending_instances: int = 0
    pending_clients: int = 0
    can_create: bool = True
    accrued_cost: float = 0.0
    burn_rate: float = 0.0
    client_rate: float = 1.0


# ---------------------------------------------------------------------------
# typed effects (outputs)
# ---------------------------------------------------------------------------
@dataclass(slots=True)
class Send:
    client: str
    mtype: MsgType
    body: Any = None
    srv_seq: int | None = None          # per-client counter (normal sends)
    ctrl_seq: int | None = None         # control-plane counter (broadcasts)


@dataclass
class CreateInstance:
    kind: str
    name: str


@dataclass
class TerminateInstance:
    name: str
    reason: str = ""


class SchedulerCore:
    """Deterministic scheduling state machine (see module docstring)."""

    def __init__(self, tasks, config: ServerConfig | None = None,
                 events: EventLog | None = None):
        self.config = config or ServerConfig()
        order = sorted(range(len(tasks)),
                       key=lambda i: tuple(tasks[i].hardness().values))
        self.tasks = [tasks[i] for i in order]        # hardness-sorted
        self.original_index = order                    # sorted pos -> orig pos
        self.status = [PENDING] * len(tasks)
        self.next_ptr = 0
        self.tasks_from_failed: collections.deque[int] = collections.deque()
        self.min_hard = MinHardSet()
        self.results: dict[int, tuple] = {}
        self.attempts: dict[int, int] = {}
        self.task_spans: dict[int, tuple] = {}   # tid -> (client, t0, t1)
        self._task_started: dict[int, tuple] = {}  # tid -> (client, t0)
        self.clients: dict[str, ClientInfo] = {}
        self.events = events or EventLog()
        self.done = False
        self._client_counter = 0
        self._budget_hit = False
        self._last_liveness = -1e18
        self.ctrl_seq = 0           # control-plane broadcast counter
        # Logical scheduling-event counters (benchmark observability).
        # Incremented per *item*, never per batch/message, so the primary
        # (batched wakes) and the backup (one-at-a-time FORWARD replay)
        # count identically and snapshots stay replay-equivalent:
        #   granted            task grants issued (incl. re-grants)
        #   acked              client message seqs acknowledged
        #   results            RESULT reports processed
        #   reports            REPORT_HARD_TASK reports processed
        #   log_entries        client LOG records (batched tids counted)
        #   domino_deliveries  hardness x client frontier deliveries
        self.stats = {"granted": 0, "acked": 0, "results": 0,
                      "reports": 0, "log_entries": 0,
                      "domino_deliveries": 0}
        self._build_policies()
        self._init_derived()

    def _build_policies(self):
        self.assign_policy = _policy.make_assign_policy(self.config)
        self.scale_policy = _policy.make_scale_policy(self.config)
        self.budget_policy = _policy.make_budget_policy(self.config)
        self.liveness_policy = _policy.make_liveness_policy(self.config)

    def _init_derived(self):
        """Derived state, rebuilt from ``status`` on both the ``__init__``
        and ``restore`` paths (like ``_build_policies``): live-task
        counters that make ``has_assignable``/``count_assignable``/
        ``_check_done`` O(1) instead of O(tasks).  Exact because every
        status write goes through ``_set_status`` and eager domino pruning
        (``_prune_dominated``) guarantees no PENDING/FAILED_POOL task is
        ever disqualified."""
        tally = collections.Counter(self.status)
        self._n_pending = tally[PENDING]
        self._n_failed = tally[FAILED_POOL]
        self._n_assigned = tally[ASSIGNED]

    def _set_status(self, tid: int, new: str):
        """Single funnel for task-status writes, keeping the live-task
        counters incrementally exact."""
        old = self.status[tid]
        if old == new:
            return
        self.status[tid] = new
        if old == PENDING:
            self._n_pending -= 1
        elif old == FAILED_POOL:
            self._n_failed -= 1
        elif old == ASSIGNED:
            self._n_assigned -= 1
        if new == PENDING:
            self._n_pending += 1
        elif new == FAILED_POOL:
            self._n_failed += 1
        elif new == ASSIGNED:
            self._n_assigned += 1

    # ------------------------------------------------------------------
    # event dispatch (replay entry point)
    # ------------------------------------------------------------------
    def handle(self, ev) -> list:
        if isinstance(ev, ClientMessage):
            return self.on_message(ev.msg, ev.now)
        if isinstance(ev, ClientJoined):
            self.client_joined(ev.name, ev.now)
            return []
        if isinstance(ev, ClientLost):
            return self.drop_client(ev.name, ev.now, reassign=ev.reassign)
        if isinstance(ev, LinkLost):
            return self.on_link_lost(ev.name, ev.now)
        if isinstance(ev, LinkHealed):
            return self.on_link_healed(ev.name, ev.now)
        if isinstance(ev, Tick):
            return self.on_tick(ev)
        raise TypeError(f"unknown scheduler event: {ev!r}")

    def handle_batch(self, events: list) -> list:
        """Dispatch a burst of events as one wake, coalescing per-client
        ACK effects into a single ``Send({"seqs": [...]})`` each and
        per-client domino broadcasts into one ``Send({"hardnesses":
        [...]})`` each, so effect cost is per-wake, not per-task.  When
        the same wake also grants to that client (or answers
        NO_FURTHER_TASKS), the ACK batch piggybacks on that message as
        ``body["acks"]`` and the separate ACK send is dropped.

        Safe to batch because both planes are *counterless* (no
        srv_seq/ctrl_seq — idempotent, order-free: outbox pops for ACKs,
        frontier unions for dominoes): the backup mirror replays
        FORWARDed messages one at a time and emits unbatched
        ``{"seq": n}`` / ``{"hardness": (...)}`` forms, and clients
        accept both shapes without any dedup-counter divergence."""
        if len(events) == 1:
            return self.handle(events[0])
        effects: list = []
        acks: dict[str, Send] = {}
        dominoes: dict[str, list] = {}
        carriers: dict[str, Send] = {}   # per-client ACK piggyback target
        for ev in events:
            for eff in self.handle(ev):
                if isinstance(eff, Send):
                    mt = eff.mtype
                    if mt is MsgType.ACK:
                        prev = acks.get(eff.client)
                        if prev is None:
                            # first ACK for this client keeps its place in
                            # the effect stream and becomes the carrier
                            eff.body = {"seqs": [eff.body["seq"]]}
                            acks[eff.client] = eff
                            effects.append(eff)
                        else:
                            prev.body["seqs"].append(eff.body["seq"])
                        continue
                    if mt is MsgType.APPLY_DOMINO_EFFECT:
                        hs = dominoes.get(eff.client)
                        if hs is None:
                            eff.body = {"hardnesses": [eff.body["hardness"]]}
                            dominoes[eff.client] = eff.body["hardnesses"]
                            effects.append(eff)
                        else:
                            hs.append(eff.body["hardness"])
                        continue
                    if mt is MsgType.GRANT_TASKS \
                            or mt is MsgType.NO_FURTHER_TASKS:
                        carriers.setdefault(eff.client, eff)
                effects.append(eff)
        # piggyback: a client that got both an ACK batch and a grant (or
        # no-further) this wake receives the acked seqs inside that
        # message instead of a separate ACK — one less message and one
        # less client wake.  Safe: acks are idempotent outbox pops, and
        # the backup's mirror (which replays unbatched and never sees the
        # piggyback) is deduped away by the carrier's srv_seq; a lost
        # carrier just means the outbox entries retry and re-ACK.
        dropped = None
        for cname, ack in acks.items():
            car = carriers.get(cname)
            if car is not None:
                body = car.body
                if body is None:
                    body = car.body = {}
                body["acks"] = ack.body["seqs"]
                if dropped is None:
                    dropped = set()
                dropped.add(id(ack))
        if dropped:
            effects = [e for e in effects if id(e) not in dropped]
        return effects

    # ------------------------------------------------------------------
    # assignment helpers (consumed by AssignPolicy implementations)
    # ------------------------------------------------------------------
    def take_failed(self):
        """Pop the next re-assignable task from the failed pool, marking
        disqualified entries PRUNED on the way.  None when exhausted."""
        while self.tasks_from_failed:
            tid = self.tasks_from_failed.popleft()
            if self.status[tid] != FAILED_POOL:
                continue
            if self.min_hard.disqualifies(self.tasks[tid].hardness()):
                self._set_status(tid, PRUNED)
                continue
            return tid, self.tasks[tid]
        return None

    def take_next(self):
        """Advance the hardness-order pointer to the next grantable task,
        marking disqualified entries PRUNED on the way."""
        while self.next_ptr < len(self.tasks):
            tid = self.next_ptr
            self.next_ptr += 1
            if self.status[tid] != PENDING:
                continue
            if self.min_hard.disqualifies(self.tasks[tid].hardness()):
                self._set_status(tid, PRUNED)
                continue
            return tid, self.tasks[tid]
        return None

    def has_assignable(self) -> bool:
        """O(1): eager domino pruning (``_prune_dominated``) keeps the
        invariant that no PENDING or FAILED_POOL task is disqualified, so
        the prune-aware live counters answer directly — no scan over
        ``range(next_ptr, len(tasks))`` (the old O(tasks)-per-tick cost
        that capped the fleet size)."""
        return self._n_failed > 0 or self._n_pending > 0

    def count_assignable(self, bound: int) -> int:
        """Number of currently grantable tasks, counted up to ``bound``.
        Pure O(1) query on the prune-aware counters: every PENDING /
        FAILED_POOL task is grantable (see ``has_assignable``)."""
        return min(bound, self._n_failed + self._n_pending)

    # ------------------------------------------------------------------
    # client lifecycle
    # ------------------------------------------------------------------
    def client_joined(self, name: str, now: float,
                      endpoint=None) -> ClientInfo:
        ci = ClientInfo(name, endpoint, now, last_active=now)
        self.clients[name] = ci
        self.events.ensure(name)
        return ci

    def register_client(self, name: str, srv_seq: int, last_client_seq: int,
                        now: float, endpoint=None) -> ClientInfo:
        """Backup-side registration from a NEW_CLIENT notification."""
        ci = ClientInfo(name, endpoint, now, srv_seq=srv_seq,
                        last_client_seq=last_client_seq, last_active=now)
        self.clients[name] = ci
        self.events.ensure(name)
        return ci

    def forget_client(self, name: str) -> None:
        """Backup-side removal from a CLIENT_TERMINATED notification."""
        self.clients.pop(name, None)

    # ------------------------------------------------------------------
    # link-state events (partition hardening)
    # ------------------------------------------------------------------
    def on_link_lost(self, cname: str, now: float) -> list:
        ci = self.clients.get(cname)
        if ci is not None and ci.suspected_at is None:
            ci.suspected_at = now
            self.events.log(cname, now, "LOG", {"event": "link_lost"})
        return []

    def on_link_healed(self, cname: str, now: float) -> list:
        ci = self.clients.get(cname)
        if ci is not None and ci.suspected_at is not None:
            ci.suspected_at = None
            # silence during the partition is explained by the partition:
            # restart the health window instead of letting the allowance
            # collapse below the accumulated silence the moment it heals
            ci.last_health = max(ci.last_health, now)
            self.events.log(cname, now, "LOG", {"event": "link_healed"})
        return []

    def drop_client(self, cname: str, now: float, reassign: bool,
                    reason: str = "unhealthy") -> list:
        """Remove a client; optionally requeue its assigned tasks.  Emits
        the TerminateInstance effect for the shell to execute."""
        ci = self.clients.pop(cname, None)
        if ci is None:
            return []
        if reassign:
            for tid in ci.assigned:
                if self.status[tid] == ASSIGNED:
                    self._set_status(tid, FAILED_POOL)
                    self.tasks_from_failed.append(tid)
        return [TerminateInstance(cname, reason)]

    def alloc_instance_name(self, prefix: str) -> str:
        name = f"{self.config.name_prefix}{prefix}-{self._client_counter}"
        self._client_counter += 1
        return name

    # ------------------------------------------------------------------
    # message handling (paper §c)
    # ------------------------------------------------------------------
    def _send(self, ci: ClientInfo, mtype, body=None) -> Send:
        eff = Send(ci.name, mtype, body, srv_seq=ci.srv_seq)
        ci.srv_seq += 1
        return eff

    def control_broadcast(self, mtype, body=None) -> list:
        """STOP/RESUME-style message to every known client.  One logical
        broadcast consumes one *control-plane* number shared by all
        recipients — per-client srv_seq is untouched, so a backup that
        missed the broadcast still agrees with the primary on every
        client's srv_seq (the backup mirrors the consumption by replaying
        the same broadcast from the primary's BROADCAST notice)."""
        seq = self.ctrl_seq
        self.ctrl_seq += 1
        return [Send(ci.name, mtype, body, ctrl_seq=seq)
                for ci in self.clients.values()]

    def on_message(self, msg: Message, now: float) -> list:
        cname = msg.sender
        ci = self.clients.get(cname)
        if ci is None:
            return []
        if msg.seq > ci.last_client_seq:
            ci.last_client_seq = msg.seq
        t = msg.type
        eff: list = []
        if t == MsgType.HEALTH_UPDATE:
            ci.last_health = now
        elif t == MsgType.REQUEST_TASKS:
            n = msg.body["n"]
            ci.capacity = max(ci.capacity, n + len(ci.assigned))
            # Re-grant assignments whose GRANT was never acknowledged and
            # has aged past the regrant timeout: a one-way server->client
            # link loss swallows grants silently, leaving tasks ASSIGNED
            # to a client that never received them — without the re-grant
            # those tasks are stranded forever (the client keeps
            # heartbeating, so liveness never requeues them).
            regrant = [(tid, ci.assigned[tid]) for tid, t0 in ci.unacked.items()
                       if now - t0 > self.config.regrant_timeout_s
                       and tid in ci.assigned]
            granted = self.assign_policy.select(self, n)
            if granted or regrant:
                ci.last_active = now
                self.stats["granted"] += len(regrant) + len(granted)
                for tid, task in granted:
                    self._set_status(tid, ASSIGNED)
                    ci.assigned[tid] = task
                for tid, _ in regrant + granted:
                    ci.unacked[tid] = now
                # echo the request size so a partial grant still settles the
                # client's whole outstanding count (see Client._act)
                eff.append(self._send(ci, MsgType.GRANT_TASKS,
                                      {"tasks": regrant + granted,
                                       "requested": n}))
            else:
                eff.append(self._send(ci, MsgType.NO_FURTHER_TASKS))
        elif t == MsgType.RESULT:
            # state-bearing reports are ACKed (by client message seq) so
            # the client can drop them from its at-least-once outbox —
            # processing below is idempotent, so duplicates just re-ACK.
            # ACKs are counterless (no srv_seq): order-free idempotent
            # pops need no dedup, and keeping them off the per-client
            # counter lets handle_batch coalesce them per wake without
            # desyncing the backup mirror's srv_seq state.
            eff.append(Send(ci.name, MsgType.ACK, {"seq": msg.seq}))
            self.stats["acked"] += 1
            # clients batch a wake's completions into one message
            # ({"results": [[tid, result], ...]}); the single-tid form is
            # kept for older traces and per-task senders
            body = msg.body
            items = body.get("results") \
                or ((body["tid"], body["result"]),)
            ci.last_active = now
            # the "done" lifecycle log entry is synthesized here rather
            # than shipped as a separate client LOG message — the RESULT
            # batch already names exactly the completed tids
            self.events.log(cname, now, "LOG",
                            {"event": "done",
                             "tids": [tid for tid, _ in items]})
            self.stats["log_entries"] += len(items)
            for tid, result in items:
                self.stats["results"] += 1
                ci.unacked.pop(tid, None)
                # Only ASSIGNED tasks may complete: a racy late result for
                # a task already TIMED_OUT/PRUNED (domino effect) or
                # already DONE (duplicate copy after takeover) must not
                # corrupt the table.
                started = self._task_started.pop(tid, None)
                if self.status[tid] == ASSIGNED:
                    self.results[tid] = tuple(result)
                    self._set_status(tid, DONE)
                    t0 = started[1] if started is not None else now
                    self.task_spans[tid] = (cname, t0, now)
                ci.assigned.pop(tid, None)
        elif t == MsgType.REPORT_HARD_TASK:
            eff.append(Send(ci.name, MsgType.ACK, {"seq": msg.seq}))
            self.stats["acked"] += 1
            # clients batch a timeout sweep into one message
            # ({"reports": [[tid, hardness], ...]}); single-tid form kept
            # for older traces and per-task senders
            body = msg.body
            items = body.get("reports") \
                or ((body["tid"], body["hardness"]),)
            ci.last_active = now
            for tid, hv in items:
                self.stats["reports"] += 1
                h = Hardness(tuple(hv))
                self._set_status(tid, TIMED_OUT)
                ci.assigned.pop(tid, None)
                ci.unacked.pop(tid, None)
                self._task_started.pop(tid, None)
                if self._absorb_hardness(h):
                    # broadcast only when the frontier actually grew: a
                    # dominated report h (some stored m <= h) prunes
                    # nothing new — by transitivity every task T >= h is
                    # also >= m and m's earlier broadcast already covered
                    # it (FIFO wires guarantee clients saw it).  At fleet
                    # scale dominated reports are the common case, so
                    # skipping the O(clients) fan-out here is what keeps
                    # timeouts cheap.
                    # Counterless like ACKs (no srv_seq/ctrl_seq):
                    # applying a hardness to a client's local queue is an
                    # idempotent, order-free frontier union, so no dedup
                    # counter is needed and handle_batch may coalesce a
                    # wake's broadcasts into one {"hardnesses": [...]}
                    # message per client.
                    self.stats["domino_deliveries"] += len(self.clients)
                    for other in self.clients.values():
                        eff.append(Send(other.name,
                                        MsgType.APPLY_DOMINO_EFFECT,
                                        {"hardness": h.values}))
        elif t == MsgType.LOG:
            self.events.log(cname, now, "LOG", msg.body)
            body = msg.body or {}
            ev_name = body.get("event")
            if ev_name == "lifecycle":
                # per-wake combined form: grant receipts + worker starts
                # in one wire message ({"granted": [...], "started": [...]})
                granted = body.get("granted") or ()
                started = body.get("started") or ()
                self.stats["log_entries"] += len(granted) + len(started)
                for tid in granted:
                    ci.unacked.pop(tid, None)
                for tid in started:
                    self._task_started[tid] = (cname, now)
                    ci.unacked.pop(tid, None)
            else:
                self.stats["log_entries"] += len(body.get("tids") or ()) or 1
                if ev_name == "started":
                    # legacy per-event form ({"tids": [...]} batched, or
                    # single-tid from older traces)
                    tids = body.get("tids") if "tids" in body else (
                        (body["tid"],) if "tid" in body else ())
                    for tid in tids:
                        self._task_started[tid] = (cname, now)
                        ci.unacked.pop(tid, None)
                elif ev_name == "granted":
                    # the client acknowledged receipt of these grants
                    for tid in body.get("tids", ()):
                        ci.unacked.pop(tid, None)
        elif t == MsgType.EXCEPTION:
            eff.append(Send(ci.name, MsgType.ACK, {"seq": msg.seq}))
            self.stats["acked"] += 1
            self.events.log(cname, now, "EXCEPTION", msg.body)
            tid = (msg.body or {}).get("tid")
            if tid is not None and self.status[tid] == ASSIGNED:
                ci.assigned.pop(tid, None)
                ci.unacked.pop(tid, None)
                ci.last_active = now
                self._task_started.pop(tid, None)
                self.attempts[tid] = self.attempts.get(tid, 1) + 1
                if self.attempts[tid] > self.config.max_task_attempts:
                    # poison task: stop retrying (would livelock otherwise)
                    self._set_status(tid, PRUNED)
                else:
                    # worker crash: send the task back to the pool
                    self._set_status(tid, FAILED_POOL)
                    self.tasks_from_failed.append(tid)
        elif t == MsgType.BYE:
            self.events.log(cname, now, "LOG", {"event": "bye"})
            # reassign=True is a no-op in the healthy flow (a client only
            # says BYE with an empty table) but saves any assignment a
            # desynced takeover still believes this client holds
            eff += self.drop_client(cname, now, reassign=True, reason="bye")
        return eff

    def _absorb_hardness(self, h: Hardness) -> bool:
        """Record a timed-out hardness; when it grows the pruning frontier
        apply the domino rule eagerly to assigned AND live (pending /
        failed-pool) tasks.  Returns True iff the frontier grew (callers
        broadcast APPLY_DOMINO_EFFECT only then).  Eager pruning is what
        keeps the live-task counters prune-aware: after this returns, no
        PENDING/FAILED_POOL task is disqualified."""
        if not self.min_hard.add(h):
            return False
        self._apply_domino(h)
        self._prune_dominated(h)
        return True

    def gossip_hardness(self, hs) -> tuple[list, list]:
        """Cross-shard domino (``core.shard``): absorb a batch of
        hardnesses observed by other shards' schedulers.  The client
        notification is counterless (no srv_seq/ctrl_seq — frontier
        unions are idempotent and order-free, like the ACK plane), so
        one gossip pump costs one message per client no matter how many
        frontier elements it delivered, and no counter state can diverge
        between primary and backup (the PR-4 bug class).  Returns
        ``(retained_values, effects)``; the shell replicates the retained
        values to the backup via the BROADCAST notice — gossip never
        arrives as a FORWARDable client message, so that notice is its
        only path into the mirror."""
        retained = [h.values for h in hs if self._absorb_hardness(h)]
        if not retained:
            return [], []
        self.stats["domino_deliveries"] += len(retained) * len(self.clients)
        return retained, [
            Send(ci.name, MsgType.APPLY_DOMINO_EFFECT,
                 {"hardnesses": list(retained)})
            for ci in self.clients.values()]

    def _apply_domino(self, h: Hardness):
        """Mark all assigned tasks dominated by h as pruned (their
        clients are terminating them; results will never arrive)."""
        for ci in self.clients.values():
            for tid in list(ci.assigned):
                if self.tasks[tid].hardness().geq(h):
                    if self.status[tid] == ASSIGNED:
                        self._set_status(tid, PRUNED)
                    ci.assigned.pop(tid, None)
                    ci.unacked.pop(tid, None)
                    self._task_started.pop(tid, None)

    def _prune_dominated(self, h: Hardness):
        """Eagerly prune live tasks dominated by a frontier-growing h.
        The old lazy scheme left them PENDING/FAILED_POOL until a grant
        scan or the completion sweep touched them, which forced every
        has_assignable/count_assignable call to re-check disqualification
        across the whole table.  One O(live) sweep per *retained* frontier
        element (rare) buys O(1) for every hot-path query."""
        for tid in range(self.next_ptr, len(self.tasks)):
            if self.status[tid] == PENDING \
                    and self.tasks[tid].hardness().geq(h):
                self._set_status(tid, PRUNED)
        for tid in self.tasks_from_failed:
            if self.status[tid] == FAILED_POOL \
                    and self.tasks[tid].hardness().geq(h):
                self._set_status(tid, PRUNED)

    # ------------------------------------------------------------------
    # periodic decisions (scaling, liveness, completion)
    # ------------------------------------------------------------------
    def on_tick(self, tick: Tick) -> list:
        eff: list = []
        # 1. fleet scaling (policy + budget), before liveness drops so the
        #    max_clients count still includes unhealthy clients — matches
        #    the paper loop's create-then-terminate order
        decision = self.scale_policy.decide(self, tick)
        if decision.create:
            if self.budget_policy is not None \
                    and not self.budget_policy.allow_create(self, tick):
                if not self._budget_hit:
                    self._budget_hit = True
                    self.events.ensure("server")
                    self.events.log(
                        "server", tick.now, "LOG",
                        {"event": "budget_cap",
                         "cap": self.budget_policy.cap,
                         "accrued": tick.accrued_cost})
            else:
                # decision.create may be > 1 (config.create_batch): one
                # tick boots a whole batch instead of serializing fleet
                # bring-up at one instance per tick
                for _ in range(decision.create):
                    eff.append(CreateInstance(
                        "client", self.alloc_instance_name("client")))
        # 2. terminate unhealthy clients (+ requeue their tasks).  Health
        #    state only changes at heartbeat granularity, so the O(clients)
        #    sweep runs at health_interval cadence, not every tick — with
        #    ready-set polling this keeps a quiet tick O(due work)
        if tick.now - self._last_liveness >= self.config.health_interval:
            self._last_liveness = tick.now
            for cname, ci in list(self.clients.items()):
                # a client whose link is reported partitioned (LinkLost)
                # gets partition_grace_s on top of the health limit — a
                # partitioned-but-alive client must not be declared dead
                # (and its tasks double-assigned) for a healable link
                if tick.now - ci.last_health > \
                        self.liveness_policy.allowance(ci):
                    self.events.log(cname, tick.now, "LOG",
                                    {"event": "unhealthy"})
                    eff += self.drop_client(cname, tick.now, reassign=True,
                                            reason="unhealthy")
        # 3. proactive idle downscale (policy may return names of clients
        #    with no assigned work; re-check so nothing is ever stranded)
        for cname in decision.terminate:
            ci = self.clients.get(cname)
            if ci is not None and not ci.assigned:
                self.events.log(cname, tick.now, "LOG",
                                {"event": "idle_downscale"})
                eff += self.drop_client(cname, tick.now, reassign=False,
                                        reason="idle")
        # 4. completion
        self._check_done()
        return eff

    def _check_done(self):
        if self.done:
            return
        # O(1) per tick on the live counters (was an O(tasks) any()-scan)
        if self._n_assigned > 0 or self.has_assignable():
            return
        # no assignable work, nothing in flight: sweep survivors (the
        # counters say there are none, but the sweep stays as a guard for
        # snapshots predating eager pruning); runs at most once
        for tid, s in enumerate(self.status):
            if s in (PENDING, FAILED_POOL):
                self._set_status(tid, PRUNED)
        self.done = True

    # ------------------------------------------------------------------
    # structured snapshot/restore (complete state; replay-equivalent)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "config": self.config,
            "tasks": self.tasks,
            "original_index": list(self.original_index),
            "status": list(self.status),
            "next_ptr": self.next_ptr,
            "tasks_from_failed": list(self.tasks_from_failed),
            "min_hard": self.min_hard.snapshot(),
            "results": dict(self.results),
            "attempts": dict(self.attempts),
            "task_spans": dict(self.task_spans),
            "task_started": dict(self._task_started),
            "clients": {
                c: {"srv_seq": ci.srv_seq,
                    "last_client_seq": ci.last_client_seq,
                    "assigned": sorted(ci.assigned),
                    "last_health": ci.last_health,
                    "capacity": ci.capacity,
                    "last_active": ci.last_active,
                    "suspected_at": ci.suspected_at,
                    "unacked": dict(ci.unacked)}
                for c, ci in self.clients.items()},
            "events": self.events.snapshot(),
            "done": self.done,
            "client_counter": self._client_counter,
            "budget_hit": self._budget_hit,
            "last_liveness": self._last_liveness,
            "ctrl_seq": self.ctrl_seq,
            "stats": dict(self.stats),
        }

    @classmethod
    def restore(cls, snap: dict) -> SchedulerCore:
        core = cls.__new__(cls)
        core.config = snap["config"]
        core.tasks = snap["tasks"]
        core.original_index = list(snap["original_index"])
        core.status = list(snap["status"])
        core.next_ptr = snap["next_ptr"]
        core.tasks_from_failed = collections.deque(snap["tasks_from_failed"])
        core.min_hard = MinHardSet()
        core.min_hard.restore(snap["min_hard"])
        core.results = dict(snap["results"])
        core.attempts = dict(snap["attempts"])
        core.task_spans = dict(snap["task_spans"])
        core._task_started = dict(snap["task_started"])
        core.clients = {}
        for cname, st in snap["clients"].items():
            core.clients[cname] = ClientInfo(
                cname, None, st["last_health"], srv_seq=st["srv_seq"],
                last_client_seq=st["last_client_seq"],
                assigned={tid: core.tasks[tid] for tid in st["assigned"]},
                capacity=st["capacity"], last_active=st["last_active"],
                suspected_at=st.get("suspected_at"),
                unacked=dict(st.get("unacked", {})))
        core.events = EventLog()
        core.events.restore(snap["events"])
        core.done = snap["done"]
        core._client_counter = snap["client_counter"]
        core._budget_hit = snap["budget_hit"]
        core._last_liveness = snap["last_liveness"]
        core.ctrl_seq = snap.get("ctrl_seq", 0)
        core.stats = dict(snap.get("stats") or {
            "granted": 0, "acked": 0, "results": 0, "reports": 0,
            "log_entries": 0, "domino_deliveries": 0})
        core._build_policies()
        core._init_derived()
        return core
