"""Pure scheduler core — a deterministic state machine over typed events.

The paper's primary-server loop is split in three here (motivated by
JobPruner's policy/mechanism separation and by Gent & Kotthoff's case for
deterministic replay on unreliable virtualized hardware):

  * **core** (this module): ``SchedulerCore`` owns the task table, the
    ``MinHardSet`` pruning antichain and the client bookkeeping.  It
    consumes typed events — ``ClientMessage``, ``ClientJoined``,
    ``ClientLost``, ``Tick`` — and emits typed effects — ``Send``,
    ``CreateInstance``, ``TerminateInstance``.  It imports **no**
    transport or engine code: the same event stream always produces the
    same effect stream and the same ``snapshot()``, which is what makes
    backup takeover "replay the forwarded stream into the same core".
  * **policies** (``repro.core.policy``): assignment order, fleet
    scaling and budget enforcement are strategy objects the core
    consults; they are rebuilt deterministically from the config.
  * **shell** (``repro.core.server``): feeds events from real channels
    and executes effects against a compute engine.

``snapshot()``/``restore()`` replace the old ad-hoc pickle blob with a
structured, complete state capture (including per-client assignment
tables and retry counters, which the old blob silently dropped).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any

from repro.core import policy as _policy
from repro.core.hardness import Hardness, MinHardSet
from repro.core.messages import Message, MsgType
from repro.core.results import EventLog

# task status values
PENDING, ASSIGNED, DONE, TIMED_OUT, PRUNED, FAILED_POOL = (
    "pending", "assigned", "done", "timed_out", "pruned", "failed_pool")


@dataclass
class ServerConfig:
    min_group_size: int = 0
    max_task_attempts: int = 3      # poison-task cap (beyond-paper)
    use_backup: bool = False
    max_clients: int = 4
    workers_hint: int = 1              # informational; pools size themselves
    health_update_limit: float = 10.0
    instance_max_non_active_time: float = 30.0
    create_backoff_init: float = 0.5
    create_backoff_max: float = 30.0
    health_interval: float = 1.0
    out_dir: str | None = None
    # policy layer (see repro.core.policy)
    assign_policy: str = "hardness"    # "hardness" | "backfill"
    assign_batch: int = 4              # batch size for "backfill"
    scale_policy: str = "fixed"        # "fixed" | "demand"
    idle_timeout_s: float = 5.0        # demand scale: idle-downscale cutoff
    budget_cap: float | None = None    # stop scaling when cap is threatened
    budget_reserve_s: float = 30.0     # projection horizon for the cap
    # partition hardening (see repro.core.policy.LivenessPolicy):
    partition_grace_s: float = 0.0     # extra liveness allowance while a
    #   client's link is reported partitioned (LinkLost) — a partitioned-
    #   but-alive client is not declared dead until limit + grace
    regrant_timeout_s: float = 6.0     # re-send an unacknowledged GRANT on
    #   the client's next request after this long (recovers grants lost to
    #   one-way server->client link loss; acked within ~2 RTT normally)


@dataclass
class ClientInfo:
    """Per-client record.  The core reads/writes everything except
    ``endpoint``, which the shell stores here for effect execution and
    which is deliberately excluded from snapshots."""

    name: str
    endpoint: Any
    last_health: float
    srv_seq: int = 0                    # per-client logical send counter
    last_client_seq: int = -1           # highest processed client msg seq
    assigned: dict = field(default_factory=dict)   # tid -> task
    capacity: int = 0                   # observed peak worker demand
    last_active: float = 0.0            # last task-lifecycle activity
    suspected_at: float | None = None   # LinkLost time (None = link fine)
    unacked: dict = field(default_factory=dict)    # tid -> grant time, not
    #   yet acknowledged (client's "granted"/"started" LOG or RESULT)


# ---------------------------------------------------------------------------
# typed events (inputs)
# ---------------------------------------------------------------------------
@dataclass
class ClientMessage:
    msg: Message
    now: float


@dataclass
class ClientJoined:
    name: str
    now: float


@dataclass
class ClientLost:
    name: str
    now: float
    reassign: bool = True


@dataclass
class LinkLost:
    """The transport reports the link to a client as (partially) down —
    the client may be partitioned-but-alive, so liveness gets
    ``partition_grace_s`` more allowance before declaring it dead."""

    name: str
    now: float


@dataclass
class LinkHealed:
    """The client's link recovered; normal liveness allowance resumes."""

    name: str
    now: float


@dataclass
class Tick:
    """Periodic decision point.  Everything the core may not observe
    directly (engine pending counts, shell backoff state, metered cost)
    arrives as event payload, so replaying ticks is deterministic.

    ``pending_instances`` counts every booting instance (the paper's
    fixed-fleet gate counts backups too); ``pending_clients`` counts
    only client-kind instances (worker capacity, used by demand
    scaling)."""

    now: float
    pending_instances: int = 0
    pending_clients: int = 0
    can_create: bool = True
    accrued_cost: float = 0.0
    burn_rate: float = 0.0
    client_rate: float = 1.0


# ---------------------------------------------------------------------------
# typed effects (outputs)
# ---------------------------------------------------------------------------
@dataclass
class Send:
    client: str
    mtype: MsgType
    body: Any = None
    srv_seq: int | None = None          # per-client counter (normal sends)
    ctrl_seq: int | None = None         # control-plane counter (broadcasts)


@dataclass
class CreateInstance:
    kind: str
    name: str


@dataclass
class TerminateInstance:
    name: str
    reason: str = ""


class SchedulerCore:
    """Deterministic scheduling state machine (see module docstring)."""

    def __init__(self, tasks, config: ServerConfig | None = None,
                 events: EventLog | None = None):
        self.config = config or ServerConfig()
        order = sorted(range(len(tasks)),
                       key=lambda i: tuple(tasks[i].hardness().values))
        self.tasks = [tasks[i] for i in order]        # hardness-sorted
        self.original_index = order                    # sorted pos -> orig pos
        self.status = [PENDING] * len(tasks)
        self.next_ptr = 0
        self.tasks_from_failed: collections.deque[int] = collections.deque()
        self.min_hard = MinHardSet()
        self.results: dict[int, tuple] = {}
        self.attempts: dict[int, int] = {}
        self.task_spans: dict[int, tuple] = {}   # tid -> (client, t0, t1)
        self._task_started: dict[int, tuple] = {}  # tid -> (client, t0)
        self.clients: dict[str, ClientInfo] = {}
        self.events = events or EventLog()
        self.done = False
        self._client_counter = 0
        self._budget_hit = False
        self._last_liveness = -1e18
        self.ctrl_seq = 0           # control-plane broadcast counter
        self._build_policies()

    def _build_policies(self):
        self.assign_policy = _policy.make_assign_policy(self.config)
        self.scale_policy = _policy.make_scale_policy(self.config)
        self.budget_policy = _policy.make_budget_policy(self.config)
        self.liveness_policy = _policy.make_liveness_policy(self.config)

    # ------------------------------------------------------------------
    # event dispatch (replay entry point)
    # ------------------------------------------------------------------
    def handle(self, ev) -> list:
        if isinstance(ev, ClientMessage):
            return self.on_message(ev.msg, ev.now)
        if isinstance(ev, ClientJoined):
            self.client_joined(ev.name, ev.now)
            return []
        if isinstance(ev, ClientLost):
            return self.drop_client(ev.name, ev.now, reassign=ev.reassign)
        if isinstance(ev, LinkLost):
            return self.on_link_lost(ev.name, ev.now)
        if isinstance(ev, LinkHealed):
            return self.on_link_healed(ev.name, ev.now)
        if isinstance(ev, Tick):
            return self.on_tick(ev)
        raise TypeError(f"unknown scheduler event: {ev!r}")

    # ------------------------------------------------------------------
    # assignment helpers (consumed by AssignPolicy implementations)
    # ------------------------------------------------------------------
    def take_failed(self):
        """Pop the next re-assignable task from the failed pool, marking
        disqualified entries PRUNED on the way.  None when exhausted."""
        while self.tasks_from_failed:
            tid = self.tasks_from_failed.popleft()
            if self.status[tid] != FAILED_POOL:
                continue
            if self.min_hard.disqualifies(self.tasks[tid].hardness()):
                self.status[tid] = PRUNED
                continue
            return tid, self.tasks[tid]
        return None

    def take_next(self):
        """Advance the hardness-order pointer to the next grantable task,
        marking disqualified entries PRUNED on the way."""
        while self.next_ptr < len(self.tasks):
            tid = self.next_ptr
            self.next_ptr += 1
            if self.status[tid] != PENDING:
                continue
            if self.min_hard.disqualifies(self.tasks[tid].hardness()):
                self.status[tid] = PRUNED
                continue
            return tid, self.tasks[tid]
        return None

    def has_assignable(self) -> bool:
        if any(self.status[t] == FAILED_POOL for t in self.tasks_from_failed):
            return True
        return any(
            self.status[tid] == PENDING
            and not self.min_hard.disqualifies(self.tasks[tid].hardness())
            for tid in range(self.next_ptr, len(self.tasks)))

    def count_assignable(self, bound: int) -> int:
        """Number of currently grantable tasks, counted up to ``bound``
        (early exit keeps scale-policy ticks O(bound)).  Pure query: does
        not mark pruned tasks."""
        c = 0
        for tid in self.tasks_from_failed:
            if self.status[tid] == FAILED_POOL \
                    and not self.min_hard.disqualifies(
                        self.tasks[tid].hardness()):
                c += 1
                if c >= bound:
                    return c
        for tid in range(self.next_ptr, len(self.tasks)):
            if self.status[tid] == PENDING \
                    and not self.min_hard.disqualifies(
                        self.tasks[tid].hardness()):
                c += 1
                if c >= bound:
                    return c
        return c

    # ------------------------------------------------------------------
    # client lifecycle
    # ------------------------------------------------------------------
    def client_joined(self, name: str, now: float,
                      endpoint=None) -> ClientInfo:
        ci = ClientInfo(name, endpoint, now, last_active=now)
        self.clients[name] = ci
        self.events.ensure(name)
        return ci

    def register_client(self, name: str, srv_seq: int, last_client_seq: int,
                        now: float, endpoint=None) -> ClientInfo:
        """Backup-side registration from a NEW_CLIENT notification."""
        ci = ClientInfo(name, endpoint, now, srv_seq=srv_seq,
                        last_client_seq=last_client_seq, last_active=now)
        self.clients[name] = ci
        self.events.ensure(name)
        return ci

    def forget_client(self, name: str) -> None:
        """Backup-side removal from a CLIENT_TERMINATED notification."""
        self.clients.pop(name, None)

    # ------------------------------------------------------------------
    # link-state events (partition hardening)
    # ------------------------------------------------------------------
    def on_link_lost(self, cname: str, now: float) -> list:
        ci = self.clients.get(cname)
        if ci is not None and ci.suspected_at is None:
            ci.suspected_at = now
            self.events.log(cname, now, "LOG", {"event": "link_lost"})
        return []

    def on_link_healed(self, cname: str, now: float) -> list:
        ci = self.clients.get(cname)
        if ci is not None and ci.suspected_at is not None:
            ci.suspected_at = None
            # silence during the partition is explained by the partition:
            # restart the health window instead of letting the allowance
            # collapse below the accumulated silence the moment it heals
            ci.last_health = max(ci.last_health, now)
            self.events.log(cname, now, "LOG", {"event": "link_healed"})
        return []

    def drop_client(self, cname: str, now: float, reassign: bool,
                    reason: str = "unhealthy") -> list:
        """Remove a client; optionally requeue its assigned tasks.  Emits
        the TerminateInstance effect for the shell to execute."""
        ci = self.clients.pop(cname, None)
        if ci is None:
            return []
        if reassign:
            for tid in ci.assigned:
                if self.status[tid] == ASSIGNED:
                    self.status[tid] = FAILED_POOL
                    self.tasks_from_failed.append(tid)
        return [TerminateInstance(cname, reason)]

    def alloc_instance_name(self, prefix: str) -> str:
        name = f"{prefix}-{self._client_counter}"
        self._client_counter += 1
        return name

    # ------------------------------------------------------------------
    # message handling (paper §c)
    # ------------------------------------------------------------------
    def _send(self, ci: ClientInfo, mtype, body=None) -> Send:
        eff = Send(ci.name, mtype, body, srv_seq=ci.srv_seq)
        ci.srv_seq += 1
        return eff

    def control_broadcast(self, mtype, body=None) -> list:
        """STOP/RESUME-style message to every known client.  One logical
        broadcast consumes one *control-plane* number shared by all
        recipients — per-client srv_seq is untouched, so a backup that
        missed the broadcast still agrees with the primary on every
        client's srv_seq (the backup mirrors the consumption by replaying
        the same broadcast from the primary's BROADCAST notice)."""
        seq = self.ctrl_seq
        self.ctrl_seq += 1
        return [Send(ci.name, mtype, body, ctrl_seq=seq)
                for ci in self.clients.values()]

    def on_message(self, msg: Message, now: float) -> list:
        cname = msg.sender
        ci = self.clients.get(cname)
        if ci is None:
            return []
        ci.last_client_seq = max(ci.last_client_seq, msg.seq)
        t = msg.type
        eff: list = []
        if t == MsgType.HEALTH_UPDATE:
            ci.last_health = now
        elif t == MsgType.REQUEST_TASKS:
            n = msg.body["n"]
            ci.capacity = max(ci.capacity, n + len(ci.assigned))
            # Re-grant assignments whose GRANT was never acknowledged and
            # has aged past the regrant timeout: a one-way server->client
            # link loss swallows grants silently, leaving tasks ASSIGNED
            # to a client that never received them — without the re-grant
            # those tasks are stranded forever (the client keeps
            # heartbeating, so liveness never requeues them).
            regrant = [(tid, ci.assigned[tid]) for tid, t0 in ci.unacked.items()
                       if now - t0 > self.config.regrant_timeout_s
                       and tid in ci.assigned]
            granted = self.assign_policy.select(self, n)
            if granted or regrant:
                ci.last_active = now
                for tid, task in granted:
                    self.status[tid] = ASSIGNED
                    ci.assigned[tid] = task
                for tid, _ in regrant + granted:
                    ci.unacked[tid] = now
                # echo the request size so a partial grant still settles the
                # client's whole outstanding count (see Client._act)
                eff.append(self._send(ci, MsgType.GRANT_TASKS,
                                      {"tasks": regrant + granted,
                                       "requested": n}))
            else:
                eff.append(self._send(ci, MsgType.NO_FURTHER_TASKS))
        elif t == MsgType.RESULT:
            # state-bearing reports are ACKed (by client message seq) so
            # the client can drop them from its at-least-once outbox —
            # processing below is idempotent, so duplicates just re-ACK
            eff.append(self._send(ci, MsgType.ACK, {"seq": msg.seq}))
            tid = msg.body["tid"]
            ci.last_active = now
            ci.unacked.pop(tid, None)
            # Only ASSIGNED tasks may complete: a racy late result for a
            # task already TIMED_OUT/PRUNED (domino effect) or already DONE
            # (duplicate copy after takeover) must not corrupt the table.
            started = self._task_started.pop(tid, None)
            if self.status[tid] == ASSIGNED:
                self.results[tid] = tuple(msg.body["result"])
                self.status[tid] = DONE
                t0 = started[1] if started is not None else now
                self.task_spans[tid] = (cname, t0, now)
            ci.assigned.pop(tid, None)
        elif t == MsgType.REPORT_HARD_TASK:
            eff.append(self._send(ci, MsgType.ACK, {"seq": msg.seq}))
            tid = msg.body["tid"]
            h = Hardness(tuple(msg.body["hardness"]))
            self.status[tid] = TIMED_OUT
            ci.assigned.pop(tid, None)
            ci.unacked.pop(tid, None)
            ci.last_active = now
            self._task_started.pop(tid, None)
            self.min_hard.add(h)
            self._apply_domino(h)
            for other in self.clients.values():
                eff.append(self._send(other, MsgType.APPLY_DOMINO_EFFECT,
                                      {"hardness": h.values}))
        elif t == MsgType.LOG:
            self.events.log(cname, now, "LOG", msg.body)
            body = msg.body or {}
            if body.get("event") == "started" and "tid" in body:
                self._task_started[body["tid"]] = (cname, now)
                ci.unacked.pop(body["tid"], None)
            elif body.get("event") == "granted":
                # the client acknowledged receipt of these grants
                for tid in body.get("tids", ()):
                    ci.unacked.pop(tid, None)
        elif t == MsgType.EXCEPTION:
            eff.append(self._send(ci, MsgType.ACK, {"seq": msg.seq}))
            self.events.log(cname, now, "EXCEPTION", msg.body)
            tid = (msg.body or {}).get("tid")
            if tid is not None and self.status[tid] == ASSIGNED:
                ci.assigned.pop(tid, None)
                ci.unacked.pop(tid, None)
                ci.last_active = now
                self._task_started.pop(tid, None)
                self.attempts[tid] = self.attempts.get(tid, 1) + 1
                if self.attempts[tid] > self.config.max_task_attempts:
                    # poison task: stop retrying (would livelock otherwise)
                    self.status[tid] = PRUNED
                else:
                    # worker crash: send the task back to the pool
                    self.status[tid] = FAILED_POOL
                    self.tasks_from_failed.append(tid)
        elif t == MsgType.BYE:
            self.events.log(cname, now, "LOG", {"event": "bye"})
            # reassign=True is a no-op in the healthy flow (a client only
            # says BYE with an empty table) but saves any assignment a
            # desynced takeover still believes this client holds
            eff += self.drop_client(cname, now, reassign=True, reason="bye")
        return eff

    def _apply_domino(self, h: Hardness):
        """Mark all assigned/pending tasks dominated by h as pruned (their
        clients are terminating them; results will never arrive)."""
        for ci in self.clients.values():
            for tid in list(ci.assigned):
                if self.tasks[tid].hardness().geq(h):
                    if self.status[tid] == ASSIGNED:
                        self.status[tid] = PRUNED
                    ci.assigned.pop(tid, None)
                    ci.unacked.pop(tid, None)
                    self._task_started.pop(tid, None)

    # ------------------------------------------------------------------
    # periodic decisions (scaling, liveness, completion)
    # ------------------------------------------------------------------
    def on_tick(self, tick: Tick) -> list:
        eff: list = []
        # 1. fleet scaling (policy + budget), before liveness drops so the
        #    max_clients count still includes unhealthy clients — matches
        #    the paper loop's create-then-terminate order
        decision = self.scale_policy.decide(self, tick)
        if decision.create:
            if self.budget_policy is not None \
                    and not self.budget_policy.allow_create(self, tick):
                if not self._budget_hit:
                    self._budget_hit = True
                    self.events.ensure("server")
                    self.events.log(
                        "server", tick.now, "LOG",
                        {"event": "budget_cap",
                         "cap": self.budget_policy.cap,
                         "accrued": tick.accrued_cost})
            else:
                eff.append(CreateInstance(
                    "client", self.alloc_instance_name("client")))
        # 2. terminate unhealthy clients (+ requeue their tasks).  Health
        #    state only changes at heartbeat granularity, so the O(clients)
        #    sweep runs at health_interval cadence, not every tick — with
        #    ready-set polling this keeps a quiet tick O(due work)
        if tick.now - self._last_liveness >= self.config.health_interval:
            self._last_liveness = tick.now
            for cname, ci in list(self.clients.items()):
                # a client whose link is reported partitioned (LinkLost)
                # gets partition_grace_s on top of the health limit — a
                # partitioned-but-alive client must not be declared dead
                # (and its tasks double-assigned) for a healable link
                if tick.now - ci.last_health > \
                        self.liveness_policy.allowance(ci):
                    self.events.log(cname, tick.now, "LOG",
                                    {"event": "unhealthy"})
                    eff += self.drop_client(cname, tick.now, reassign=True,
                                            reason="unhealthy")
        # 3. proactive idle downscale (policy may return names of clients
        #    with no assigned work; re-check so nothing is ever stranded)
        for cname in decision.terminate:
            ci = self.clients.get(cname)
            if ci is not None and not ci.assigned:
                self.events.log(cname, tick.now, "LOG",
                                {"event": "idle_downscale"})
                eff += self.drop_client(cname, tick.now, reassign=False,
                                        reason="idle")
        # 4. completion
        self._check_done()
        return eff

    def _check_done(self):
        if self.done:
            return
        if any(s == ASSIGNED for s in self.status) or self.has_assignable():
            return
        # no assignable work, nothing in flight: sweep survivors
        for tid, s in enumerate(self.status):
            if s in (PENDING, FAILED_POOL):
                self.status[tid] = PRUNED
        self.done = True

    # ------------------------------------------------------------------
    # structured snapshot/restore (complete state; replay-equivalent)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "config": self.config,
            "tasks": self.tasks,
            "original_index": list(self.original_index),
            "status": list(self.status),
            "next_ptr": self.next_ptr,
            "tasks_from_failed": list(self.tasks_from_failed),
            "min_hard": self.min_hard.snapshot(),
            "results": dict(self.results),
            "attempts": dict(self.attempts),
            "task_spans": dict(self.task_spans),
            "task_started": dict(self._task_started),
            "clients": {
                c: {"srv_seq": ci.srv_seq,
                    "last_client_seq": ci.last_client_seq,
                    "assigned": sorted(ci.assigned),
                    "last_health": ci.last_health,
                    "capacity": ci.capacity,
                    "last_active": ci.last_active,
                    "suspected_at": ci.suspected_at,
                    "unacked": dict(ci.unacked)}
                for c, ci in self.clients.items()},
            "events": self.events.snapshot(),
            "done": self.done,
            "client_counter": self._client_counter,
            "budget_hit": self._budget_hit,
            "last_liveness": self._last_liveness,
            "ctrl_seq": self.ctrl_seq,
        }

    @classmethod
    def restore(cls, snap: dict) -> SchedulerCore:
        core = cls.__new__(cls)
        core.config = snap["config"]
        core.tasks = snap["tasks"]
        core.original_index = list(snap["original_index"])
        core.status = list(snap["status"])
        core.next_ptr = snap["next_ptr"]
        core.tasks_from_failed = collections.deque(snap["tasks_from_failed"])
        core.min_hard = MinHardSet()
        core.min_hard.restore(snap["min_hard"])
        core.results = dict(snap["results"])
        core.attempts = dict(snap["attempts"])
        core.task_spans = dict(snap["task_spans"])
        core._task_started = dict(snap["task_started"])
        core.clients = {}
        for cname, st in snap["clients"].items():
            core.clients[cname] = ClientInfo(
                cname, None, st["last_health"], srv_seq=st["srv_seq"],
                last_client_seq=st["last_client_seq"],
                assigned={tid: core.tasks[tid] for tid in st["assigned"]},
                capacity=st["capacity"], last_active=st["last_active"],
                suspected_at=st.get("suspected_at"),
                unacked=dict(st.get("unacked", {})))
        core.events = EventLog()
        core.events.restore(snap["events"])
        core.done = snap["done"]
        core._client_counter = snap["client_counter"]
        core._budget_hit = snap["budget_hit"]
        core._last_liveness = snap["last_liveness"]
        core.ctrl_seq = snap.get("ctrl_seq", 0)
        core._build_policies()
        return core
