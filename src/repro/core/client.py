"""The client (paper §"The clients").

Main-loop actions per iteration (paper order):
  1. send health update to the servers,
  2. process worker events,
  3. request tasks subject to idle workers (counting outstanding requests),
  4. process messages from the primary (and dedup the backup's mirrors),
  5. start workers for granted tasks,
plus timeout enforcement (terminate + REPORT_HARD_TASK) and the domino
effect.  Exits when NO_FURTHER_TASKS was received and all workers are done;
sends BYE so the server can delete this instance (cost saving).
"""
from __future__ import annotations

import collections
import time

from repro.core.hardness import Hardness
from repro.core.messages import Message, MsgType
from repro.core.workerpool import WorkerEvent


class Client:
    def __init__(self, name, primary_channel, backup_channel, pool, clock,
                 handshake=None, health_interval: float = 1.0,
                 request_retry: float = 8.0):
        self.name = name
        self.primary = primary_channel
        self.backup = backup_channel
        self.pool = pool
        self.clock = clock
        self.health_interval = health_interval
        self._last_health = -1e18
        # outstanding task requests are presumed lost (one-way link loss
        # drops GRANTs silently) after this long and re-issued; grants
        # normally settle within ~2 RTT so healthy runs never retry
        self.request_retry = request_retry
        self._last_request = -1e18

        self.tasks: dict[int, object] = {}     # tid -> task (granted)
        self.queue: collections.deque[int] = collections.deque()  # granted,
        #   not yet started (deque: starts pop from the front in O(1))
        self.outstanding = 0                   # requested, not yet granted
        self.no_further = False
        self.stopped = False
        self.finished = False

        # two-copy dedup state (srv_seq: per-client sends; ctrl_seq:
        # control broadcasts — separate counter spaces, separate sets)
        self._processed_srv_seqs: set[int] = set()
        self._processed_ctrl_seqs: set[int] = set()
        self._backup_buffer: list[Message] = []

        # at-least-once delivery for state-bearing reports: RESULT /
        # REPORT_HARD_TASK / EXCEPTION stay in the outbox (same Message,
        # same seq — the server's handling is idempotent) and are re-sent
        # until the server ACKs them, so a partition that swallows a
        # RESULT cannot strand its task in ASSIGNED forever
        self._outbox: dict[int, list] = {}     # msg.seq -> [Message, t_sent]

        if handshake is not None:
            handshake.send(Message(MsgType.HANDSHAKE, self.name,
                                   body={"kind": "client"}))

    # ------------------------------------------------------------------
    _NEEDS_ACK = (MsgType.RESULT, MsgType.REPORT_HARD_TASK,
                  MsgType.EXCEPTION)

    def send_to_servers(self, mtype, body=None):
        msg = Message(mtype, self.name, body)
        self.primary.send(msg)
        if self.backup is not None:
            self.backup.send(msg)    # the copy (same seq) for the backup
        if mtype in self._NEEDS_ACK:
            self._outbox[msg.seq] = [msg, self.clock()]

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One main-loop iteration; returns True when the client is done."""
        now = self.clock()
        # 1. health updates (sent even while STOPped — paper)
        if now - self._last_health >= self.health_interval:
            self.send_to_servers(MsgType.HEALTH_UPDATE)
            self._last_health = now

        # 2. worker events
        for ev in self.pool.poll():
            if ev.kind == WorkerEvent.STARTED:
                self.send_to_servers(MsgType.LOG,
                                     {"event": "started", "tid": ev.task_id})
            elif ev.kind == WorkerEvent.DONE:
                self.send_to_servers(MsgType.RESULT,
                                     {"tid": ev.task_id, "result": ev.payload})
                self.send_to_servers(MsgType.LOG,
                                     {"event": "done", "tid": ev.task_id})
                self.tasks.pop(ev.task_id, None)
            elif ev.kind == WorkerEvent.ERROR:
                self.send_to_servers(MsgType.EXCEPTION,
                                     {"tid": ev.task_id, "error": ev.payload})
                self.tasks.pop(ev.task_id, None)

        # 6 (interleaved). timeout enforcement
        for tid, t0 in list(self.pool.running().items()):
            task = self.tasks.get(tid)
            if task is None:
                continue
            deadline = task.timeout()
            if deadline is not None and now - t0 > deadline:
                self.pool.terminate(tid)
                self.tasks.pop(tid, None)
                self.send_to_servers(
                    MsgType.REPORT_HARD_TASK,
                    {"tid": tid, "hardness": task.hardness().values})
                self.send_to_servers(MsgType.LOG,
                                     {"event": "timeout", "tid": tid})

        # 2b. re-send unacknowledged reports (lost to a partition)
        for _seq, entry in list(self._outbox.items()):
            msg, t_sent = entry
            if now - t_sent > self.request_retry:
                self.primary.send(msg)
                if self.backup is not None:
                    self.backup.send(msg)
                entry[1] = now

        # 3. request tasks (an unanswered request eventually retries —
        #    its GRANT may have been lost to a partition)
        if not self.stopped and not self.no_further:
            if self.outstanding > 0 \
                    and now - self._last_request > self.request_retry:
                self.outstanding = 0
            want = self.pool.idle() - self.outstanding - len(self.queue)
            if want > 0:
                self.send_to_servers(MsgType.REQUEST_TASKS, {"n": want})
                self.outstanding += want
                self._last_request = now

        # 4. process messages
        while True:
            msg = self.primary.poll()
            if msg is None:
                break
            self._act(msg)
        if self.backup is not None:
            while True:
                msg = self.backup.poll()
                if msg is None:
                    break
                self._buffer_backup(msg)

        # 5. start workers
        if not self.stopped:
            while self.queue and self.pool.idle() > 0:
                tid = self.queue.popleft()
                if tid in self.tasks:
                    self.pool.start(tid, self.tasks[tid])

        # exit condition (pending un-ACKed reports hold the client alive:
        # saying BYE before the server confirmed receipt loses results)
        if self.no_further and not self.queue and not self.tasks \
                and not self.pool.running() and not self._outbox \
                and not self.finished:
            self.send_to_servers(MsgType.BYE)
            self.finished = True
        return self.finished

    def run(self, poll_sleep: float = 0.02):
        while not self.step():
            time.sleep(poll_sleep)
        self.pool.shutdown()

    def next_wake(self, now: float) -> float:
        """Earliest future time this client needs attention absent incoming
        messages or worker completions: the next health heartbeat or the
        earliest running task's deadline.  Scheduling hint for the
        discrete-event simulator; no effect on protocol semantics."""
        nxt = self._last_health + self.health_interval
        next_done = getattr(self.pool, "next_completion", lambda: None)()
        if next_done is not None:
            nxt = min(nxt, next_done)
        for tid, t0 in self.pool.running().items():
            task = self.tasks.get(tid)
            if task is None:
                continue
            deadline = task.timeout()
            if deadline is not None:
                # timeout check is strict (now - t0 > deadline)
                nxt = min(nxt, t0 + deadline + 1e-6)
        return max(nxt, now + 1e-6)

    # ------------------------------------------------------------------
    def _buffer_backup(self, msg: Message):
        if msg.type == MsgType.SWAP_QUEUES:
            # arrives on the backup-turned-primary path too; handle directly
            self._act(msg)
            return
        if msg.srv_seq is not None and msg.srv_seq in self._processed_srv_seqs:
            return  # mirror of an already-processed primary message: pop
        if msg.ctrl_seq is not None \
                and msg.ctrl_seq in self._processed_ctrl_seqs:
            return  # mirror of an already-processed control broadcast
        self._backup_buffer.append(msg)

    def _act(self, msg: Message):
        if msg.ctrl_seq is not None:
            if msg.ctrl_seq in self._processed_ctrl_seqs:
                return
            self._processed_ctrl_seqs.add(msg.ctrl_seq)
            self._backup_buffer = [
                m for m in self._backup_buffer
                if m.ctrl_seq != msg.ctrl_seq]
        elif msg.srv_seq is not None:
            if msg.srv_seq in self._processed_srv_seqs:
                return
            self._processed_srv_seqs.add(msg.srv_seq)
            # pop any buffered mirror of this message
            self._backup_buffer = [
                m for m in self._backup_buffer
                if m.srv_seq != msg.srv_seq]
        t = msg.type
        if t == MsgType.ACK:
            self._outbox.pop(msg.body["seq"], None)
        elif t == MsgType.GRANT_TASKS:
            granted = msg.body["tasks"]   # list[(tid, task)]
            # The server echoes how many tasks the request asked for; a
            # partial grant (fewer tasks than requested) must still settle
            # the whole request, otherwise the shortfall stays counted as
            # outstanding forever and this client under-requests for the
            # rest of the run, idling workers.
            requested = msg.body.get("requested", len(granted))
            self.outstanding = max(0, self.outstanding - requested)
            for tid, task in granted:
                if tid in self.tasks:
                    continue   # re-granted while the original survived
                self.tasks[tid] = task
                self.queue.append(tid)
            self.send_to_servers(
                MsgType.LOG, {"event": "granted",
                              "tids": [tid for tid, _ in granted]})
        elif t == MsgType.NO_FURTHER_TASKS:
            self.no_further = True
            self.outstanding = 0
        elif t == MsgType.APPLY_DOMINO_EFFECT:
            h = Hardness(tuple(msg.body["hardness"]))
            for tid in list(self.pool.running()):
                task = self.tasks.get(tid)
                if task is not None and task.hardness().geq(h):
                    self.pool.terminate(tid)
                    self.tasks.pop(tid, None)
                    self.send_to_servers(
                        MsgType.LOG, {"event": "dominoed", "tid": tid})
            for tid in list(self.queue):
                task = self.tasks.get(tid)
                if task is not None and task.hardness().geq(h):
                    self.queue.remove(tid)
                    self.tasks.pop(tid, None)
        elif t == MsgType.STOP:
            self.stopped = True
        elif t == MsgType.RESUME:
            self.stopped = False
        elif t == MsgType.SWAP_QUEUES:
            # the backup became the primary: swap the channel pair and
            # process the backup's buffered (unmatched) messages in order.
            # The message carries a fresh backup-channel end (the engine
            # re-registered the queues) — pointing `backup` at the old
            # object would double-send every message to the new primary.
            if self.backup is not None:
                self.primary = self.backup
            self.backup = (msg.body or {}).get("new_backup")
            buffered, self._backup_buffer = self._backup_buffer, []
            # control broadcasts (srv_seq None) sort ahead of data sends;
            # within each space the counters give the true order
            for m in sorted(buffered,
                            key=lambda m: (0, m.ctrl_seq or 0)
                            if m.srv_seq is None else (1, m.srv_seq)):
                self._act(m)
