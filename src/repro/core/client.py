"""The client (paper §"The clients").

Main-loop actions per iteration (paper order):
  1. send health update to the servers,
  2. process worker events,
  3. request tasks subject to idle workers (counting outstanding requests),
  4. process messages from the primary (and dedup the backup's mirrors),
  5. start workers for granted tasks,
plus timeout enforcement (terminate + REPORT_HARD_TASK) and the domino
effect.  Exits when NO_FURTHER_TASKS was received and all workers are done;
sends BYE so the server can delete this instance (cost saving).
"""
from __future__ import annotations

import collections
import heapq
import time

from repro.core.hardness import Hardness
from repro.core.messages import Message, MsgType
from repro.core.workerpool import WorkerEvent


class Client:
    def __init__(self, name, primary_channel, backup_channel, pool, clock,
                 handshake=None, health_interval: float = 1.0,
                 request_retry: float = 8.0):
        self.name = name
        self.primary = primary_channel
        self.backup = backup_channel
        self.pool = pool
        # zero-copy running view when the pool offers one (hot-path
        # sweeps run every step; copying the dict three times per wake
        # dominated fleet-scale client cost)
        self._pool_running = getattr(pool, "running_ref", pool.running)
        self._drain_started = getattr(pool, "drain_started", None)
        # grant receipts acknowledged this step; flushed with the started
        # tids as one "lifecycle" LOG after phase 5 instead of one wire
        # message per GRANT
        self._granted_pending: list[int] = []
        self.clock = clock
        self.health_interval = health_interval
        self._last_health = -1e18
        # outstanding task requests are presumed lost (one-way link loss
        # drops GRANTs silently) after this long and re-issued; grants
        # normally settle within ~2 RTT so healthy runs never retry
        self.request_retry = request_retry
        self._last_request = -1e18

        self.tasks: dict[int, object] = {}     # tid -> task (granted)
        self.queue: collections.deque[int] = collections.deque()  # granted,
        #   not yet started (deque: starts pop from the front in O(1))
        # (deadline, tid) min-heap of running tasks' timeout instants —
        # the per-step sweep and next_wake pop/peek this instead of
        # scanning every running task every wake.  Entries go stale when
        # a task completes or is terminated (domino/regrant); consumers
        # verify against the live running set and drop or re-push
        self._deadline_heap: list[tuple[float, int]] = []
        self.outstanding = 0                   # requested, not yet granted
        self.no_further = False
        self.stopped = False
        self.finished = False

        # two-copy dedup state (srv_seq: per-client sends; ctrl_seq:
        # control broadcasts — separate counter spaces, separate sets)
        self._processed_srv_seqs: set[int] = set()
        self._processed_ctrl_seqs: set[int] = set()
        self._backup_buffer: list[Message] = []

        # at-least-once delivery for state-bearing reports: RESULT /
        # REPORT_HARD_TASK / EXCEPTION stay in the outbox (same Message,
        # same seq — the server's handling is idempotent) and are re-sent
        # until the server ACKs them, so a partition that swallows a
        # RESULT cannot strand its task in ASSIGNED forever
        self._outbox: dict[int, list] = {}     # msg.seq -> [Message, t_sent]

        if handshake is not None:
            handshake.send(Message(MsgType.HANDSHAKE, self.name,
                                   body={"kind": "client"}))

    # ------------------------------------------------------------------
    _NEEDS_ACK = (MsgType.RESULT, MsgType.REPORT_HARD_TASK,
                  MsgType.EXCEPTION)

    def send_to_servers(self, mtype, body=None):
        msg = Message(mtype, self.name, body)
        self.primary.send(msg)
        if self.backup is not None:
            self.backup.send(msg)    # the copy (same seq) for the backup
        if mtype in self._NEEDS_ACK:
            self._outbox[msg.seq] = [msg, self.clock()]

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One main-loop iteration; returns True when the client is done."""
        now = self.clock()
        # 1. health updates (sent even while STOPped — paper)
        if now - self._last_health >= self.health_interval:
            self.send_to_servers(MsgType.HEALTH_UPDATE)
            self._last_health = now

        # 2. worker events — uploads are batched per wake: lifecycle LOGs
        #    as one {"tids": [...]} message and RESULTs as one
        #    {"results": [[tid, result], ...]} message riding a single
        #    at-least-once outbox entry (the server's per-item handling is
        #    idempotent, so a retried batch just re-applies no-ops).
        #    EXCEPTION stays per-task (rare, carries a traceback payload).
        #    No separate "done" LOG rides the wire: the server synthesizes
        #    the log entry from the RESULT batch itself
        started: list = []
        results: list = []
        for ev in self.pool.poll():
            if ev.kind == WorkerEvent.STARTED:
                started.append(ev.task_id)
            elif ev.kind == WorkerEvent.DONE:
                results.append((ev.task_id, ev.payload))
                self.tasks.pop(ev.task_id, None)
            elif ev.kind == WorkerEvent.ERROR:
                self.send_to_servers(MsgType.EXCEPTION,
                                     {"tid": ev.task_id, "error": ev.payload})
                self.tasks.pop(ev.task_id, None)
        if results:
            self.send_to_servers(MsgType.RESULT, {"results": results})
        # the "started" LOG is sent after phase 5, so tasks started later
        # this same step (sim pools drain synchronously) ride along

        # 6 (interleaved). timeout enforcement: pop due entries off the
        # deadline heap instead of scanning every running task every wake
        # (collect first, mutate after).  A popped entry is re-verified
        # against the live running set — completed/terminated tasks left
        # stale entries, and a re-granted task's fresh start time gets a
        # corrected entry pushed back
        timed_out = None
        heap = self._deadline_heap
        if heap and heap[0][0] < now:
            running = self._pool_running()
            while heap and heap[0][0] < now:
                _, tid = heapq.heappop(heap)
                task = self.tasks.get(tid)
                if task is None:
                    continue
                t0 = running.get(tid)
                if t0 is None:
                    continue
                deadline = task.timeout()
                if deadline is None:
                    continue
                if now - t0 > deadline:
                    if timed_out is None:
                        timed_out = []
                    timed_out.append((tid, task))
                else:
                    heapq.heappush(heap, (t0 + deadline, tid))
        if timed_out:
            for tid, task in timed_out:
                self.pool.terminate(tid)
                self.tasks.pop(tid, None)
            # one batched report + one batched LOG for the whole sweep
            self.send_to_servers(
                MsgType.REPORT_HARD_TASK,
                {"reports": [(tid, task.hardness().values)
                             for tid, task in timed_out]})
            self.send_to_servers(
                MsgType.LOG,
                {"event": "timeout", "tids": [tid for tid, _ in timed_out]})

        # 2b. re-send unacknowledged reports (lost to a partition)
        for _seq, entry in list(self._outbox.items()) if self._outbox else ():
            msg, t_sent = entry
            if now - t_sent > self.request_retry:
                self.primary.send(msg)
                if self.backup is not None:
                    self.backup.send(msg)
                entry[1] = now

        # 3. request tasks (an unanswered request eventually retries —
        #    its GRANT may have been lost to a partition)
        if not self.stopped and not self.no_further:
            if self.outstanding > 0 \
                    and now - self._last_request > self.request_retry:
                self.outstanding = 0
            want = self.pool.idle() - self.outstanding - len(self.queue)
            if want > 0:
                self.send_to_servers(MsgType.REQUEST_TASKS, {"n": want})
                self.outstanding += want
                self._last_request = now

        # 4. process messages
        while True:
            msg = self.primary.poll()
            if msg is None:
                break
            self._act(msg)
        if self.backup is not None:
            while True:
                msg = self.backup.poll()
                if msg is None:
                    break
                self._buffer_backup(msg)

        # 5. start workers
        if not self.stopped:
            while self.queue and self.pool.idle() > 0:
                tid = self.queue.popleft()
                task = self.tasks.get(tid)
                if task is not None:
                    self.pool.start(tid, task)
                    deadline = task.timeout()
                    if deadline is not None:
                        heapq.heappush(self._deadline_heap,
                                       (now + deadline, tid))
        # sim pools surface STARTED synchronously (drain_started) so the
        # lifecycle LOG for tasks started *this* step goes out now rather
        # than one wake later; process pools report via phase 2 instead
        if self._drain_started is not None:
            started.extend(self._drain_started())
        # one combined lifecycle LOG per wake: grant receipts (phase 4)
        # and worker starts (phase 5) ride the same message
        if started or self._granted_pending:
            granted_ack = self._granted_pending
            self._granted_pending = []
            self.send_to_servers(MsgType.LOG,
                                 {"event": "lifecycle",
                                  "granted": granted_ack,
                                  "started": started})

        # exit condition (pending un-ACKed reports hold the client alive:
        # saying BYE before the server confirmed receipt loses results)
        if self.no_further and not self.queue and not self.tasks \
                and not self._pool_running() and not self._outbox \
                and not self.finished:
            self.send_to_servers(MsgType.BYE)
            self.finished = True
        return self.finished

    def run(self, poll_sleep: float = 0.02):
        while not self.step():
            time.sleep(poll_sleep)
        self.pool.shutdown()

    def next_wake(self, now: float) -> float:
        """Earliest future time this client needs attention absent incoming
        messages or worker completions: the next health heartbeat or the
        earliest running task's deadline.  Scheduling hint for the
        discrete-event simulator; no effect on protocol semantics."""
        nxt = self._last_health + self.health_interval
        next_done = getattr(self.pool, "next_completion", lambda: None)()
        if next_done is not None:
            nxt = min(nxt, next_done)
        # earliest plausible deadline: peek the heap, lazily dropping
        # entries whose task is gone.  A stale-early entry (re-granted
        # task) only wakes the client sooner than needed — the sweep
        # re-verifies and corrects it
        heap = self._deadline_heap
        running = self._pool_running() if heap else None
        while heap:
            dl, tid = heap[0]
            if tid not in self.tasks or tid not in running:
                heapq.heappop(heap)
                continue
            # timeout check is strict (now - t0 > deadline)
            nxt = min(nxt, dl + 1e-6)
            break
        return max(nxt, now + 1e-6)

    # ------------------------------------------------------------------
    def _buffer_backup(self, msg: Message):
        if msg.type == MsgType.SWAP_QUEUES:
            # arrives on the backup-turned-primary path too; handle directly
            self._act(msg)
            return
        if msg.srv_seq is None and msg.ctrl_seq is None:
            # counterless plane (ACKs, domino broadcasts): no counter to
            # match a primary copy against, so act on the mirror
            # immediately — outbox pops and frontier unions are
            # idempotent, and buffering would accumulate them forever
            self._act(msg)
            return
        if msg.srv_seq is not None and msg.srv_seq in self._processed_srv_seqs:
            return  # mirror of an already-processed primary message: pop
        if msg.ctrl_seq is not None \
                and msg.ctrl_seq in self._processed_ctrl_seqs:
            return  # mirror of an already-processed control broadcast
        self._backup_buffer.append(msg)

    def _act(self, msg: Message):
        if msg.ctrl_seq is not None:
            if msg.ctrl_seq in self._processed_ctrl_seqs:
                return
            self._processed_ctrl_seqs.add(msg.ctrl_seq)
            self._backup_buffer = [
                m for m in self._backup_buffer
                if m.ctrl_seq != msg.ctrl_seq]
        elif msg.srv_seq is not None:
            if msg.srv_seq in self._processed_srv_seqs:
                return
            self._processed_srv_seqs.add(msg.srv_seq)
            # pop any buffered mirror of this message
            self._backup_buffer = [
                m for m in self._backup_buffer
                if m.srv_seq != msg.srv_seq]
        t = msg.type
        if t == MsgType.ACK:
            # single {"seq": n} (backup mirror / unbatched) or coalesced
            # {"seqs": [...]} (primary's per-wake batch) — both idempotent
            body = msg.body or {}
            for seq in body.get("seqs") or (body.get("seq"),):
                self._outbox.pop(seq, None)
        elif t == MsgType.GRANT_TASKS:
            # the server may piggyback ACKed seqs on the grant (same-wake
            # coalescing) — idempotent outbox pops, mirror-safe
            for seq in msg.body.get("acks") or ():
                self._outbox.pop(seq, None)
            granted = msg.body["tasks"]   # list[(tid, task)]
            # The server echoes how many tasks the request asked for; a
            # partial grant (fewer tasks than requested) must still settle
            # the whole request, otherwise the shortfall stays counted as
            # outstanding forever and this client under-requests for the
            # rest of the run, idling workers.
            requested = msg.body.get("requested", len(granted))
            self.outstanding = max(0, self.outstanding - requested)
            for tid, task in granted:
                if tid in self.tasks:
                    continue   # re-granted while the original survived
                self.tasks[tid] = task
                self.queue.append(tid)
            # receipt is flushed after phase 5 in the combined
            # "lifecycle" LOG (one wire message per wake, not per grant)
            self._granted_pending.extend(tid for tid, _ in granted)
        elif t == MsgType.NO_FURTHER_TASKS:
            for seq in (msg.body or {}).get("acks") or ():
                self._outbox.pop(seq, None)
            self.no_further = True
            self.outstanding = 0
        elif t == MsgType.APPLY_DOMINO_EFFECT:
            # single {"hardness": (...)} (backup mirror / unbatched) or
            # coalesced {"hardnesses": [...]} (per-wake batch / gossip
            # pump) — both idempotent frontier unions
            body = msg.body or {}
            hs = [Hardness(tuple(v))
                  for v in body.get("hardnesses") or (body["hardness"],)]
            for tid in list(self.pool.running()):
                task = self.tasks.get(tid)
                if task is not None \
                        and any(task.hardness().geq(h) for h in hs):
                    self.pool.terminate(tid)
                    self.tasks.pop(tid, None)
                    self.send_to_servers(
                        MsgType.LOG, {"event": "dominoed", "tid": tid})
            for tid in list(self.queue):
                task = self.tasks.get(tid)
                if task is not None \
                        and any(task.hardness().geq(h) for h in hs):
                    self.queue.remove(tid)
                    self.tasks.pop(tid, None)
        elif t == MsgType.STOP:
            self.stopped = True
        elif t == MsgType.RESUME:
            self.stopped = False
        elif t == MsgType.SWAP_QUEUES:
            # the backup became the primary: swap the channel pair and
            # process the backup's buffered (unmatched) messages in order.
            # The message carries a fresh backup-channel end (the engine
            # re-registered the queues) — pointing `backup` at the old
            # object would double-send every message to the new primary.
            if self.backup is not None:
                self.primary = self.backup
            self.backup = (msg.body or {}).get("new_backup")
            buffered, self._backup_buffer = self._backup_buffer, []
            # control broadcasts (srv_seq None) sort ahead of data sends;
            # within each space the counters give the true order
            for m in sorted(buffered,
                            key=lambda m: (0, m.ctrl_seq or 0)
                            if m.srv_seq is None else (1, m.srv_seq)):
                self._act(m)
