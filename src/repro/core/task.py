"""AbstractTask — the researcher-facing task interface (paper §example).

Subclasses provide parameters / hardness / run / result titles; the
framework owns ordering, assignment, timeout and the domino effect.
"""
from __future__ import annotations

from repro.core.hardness import Hardness


def filter_out(titles, excluded):
    return tuple(t for t in titles if t not in excluded)


class AbstractTask:
    """Subclass and override. A task must be picklable (it crosses process
    boundaries to workers and, serialized, to the backup server)."""

    Hardness = Hardness

    # --- identity / reporting ------------------------------------------
    def parameter_titles(self) -> tuple:
        raise NotImplementedError

    def parameters(self) -> tuple:
        raise NotImplementedError

    def result_titles(self) -> tuple:
        raise NotImplementedError

    # --- hardness -------------------------------------------------------
    def hardness_parameters(self) -> tuple:
        """Subset of parameters that correlates with execution time."""
        raise NotImplementedError

    def hardness(self) -> Hardness:
        # cached: hardness parameters are immutable for a task's lifetime
        # and hot paths (assignment scans, domino checks, timeout sweeps)
        # ask repeatedly
        h = getattr(self, "_hardness", None)
        if h is None:
            h = self.Hardness(tuple(self.hardness_parameters()))
            self._hardness = h
        return h

    # --- execution -------------------------------------------------------
    def run(self) -> tuple:
        """Execute; return the tuple matching result_titles()."""
        raise NotImplementedError

    def timeout(self) -> float | None:
        """Per-task deadline in seconds (None = no deadline)."""
        return None

    # --- grouping (min_group_size retention) ------------------------------
    def group_parameter_titles(self) -> tuple:
        return filter_out(self.parameter_titles(), ("id",))

    def group_key(self) -> tuple:
        titles = self.parameter_titles()
        params = self.parameters()
        gset = set(self.group_parameter_titles())
        return tuple(
            v for t, v in zip(titles, params, strict=False) if t in gset)
