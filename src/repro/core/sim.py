"""Deterministic cloud simulator (virtual clock, discrete-event core).

The paper's local engine "is actually a simulation of performing the
experiment on the cloud ... a powerful tool to facilitate further
development".  We take that seriously: ``SimEngine`` runs the *same*
Server/Client protocol code as the real engines, but on a virtual clock
with scripted instance-creation delays, rate limits, message latency and
failure injection — so the fault-tolerance protocol (backup mirroring,
takeover, task reassignment, domino effect) is unit-testable and
benchmarkable with exact reproducibility.

The core is a **discrete-event engine**: a global event heap holds message
deliveries, worker completions, instance materializations, script
callbacks and per-node wake hints (health heartbeats, task deadlines,
creation-backoff expiries).  ``SimCluster.run()`` jumps the clock to the
next event and steps only the nodes that event concerns, doing O(events)
work instead of O(T/dt * nodes) polling.  The legacy fixed-``dt`` polling
loop is retained behind ``SimParams(mode="fixed")`` as a semantic
reference for equivalence tests and speedup benchmarks.

Scenario knobs the fixed-step loop could not afford:
  * heterogeneous instance types — per-kind ``creation_delay``,
    ``cost_per_instance_second`` and ``client_workers``
    (``SimParams.instance_types``),
  * scripted spot-preemption waves (``SimCluster.spot_wave``),
  * per-message latency jitter from a seeded RNG
    (``SimParams.latency_jitter`` / ``SimParams.seed``).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import pickle
import random
import warnings
from dataclasses import dataclass, field

from repro.core import transport
from repro.core.client import Client
from repro.core.engine import AbstractEngine, PendingInstance, RateLimited
from repro.core.server import Server, ServerConfig
from repro.core.shard import (ShardCoordinator, merge_results,
                              partition_tasks, pump_gossip)
from repro.core.task import AbstractTask
from repro.core.trace import TraceRecorder, TraceReplayer, as_trace
from repro.core.workerpool import SimWorkerPool


class Clock:
    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt

    def advance_to(self, t: float):
        if t > self.t:
            self.t = t


# wake target meaning "every alive server node" — server-side wires cannot
# name their poller statically (the acting primary changes at takeover)
SERVERS = "@servers"


class EventLoop:
    """Global event heap over the virtual clock.

    Entries are ``(time, seq, kind, data)``; ``seq`` makes heap order
    deterministic for same-time events (insertion order).  ``wake`` entries
    are deduplicated per target: scheduling a wake at or after an already
    pending one is a no-op, so periodic rescheduling stays O(1) per event.
    """

    def __init__(self, clock: Clock):
        self.clock = clock
        self._heap: list = []
        self._seq = itertools.count()
        self._pending_wake: dict = {}     # target -> earliest scheduled t
        self.enabled = True               # disabled under mode="fixed"
        self.processed = 0                # events popped (benchmark metric)

    def schedule(self, t: float, kind: str, data=None):
        if not self.enabled:
            return
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    def wake(self, target, t: float, quantum: float = 0.0):
        """Request that ``target`` be stepped at time ``t`` (coalesced up to
        ``quantum`` to batch near-simultaneous deliveries into one step)."""
        if not self.enabled:
            return
        if quantum > 0.0:
            q_t = math.ceil(round(t / quantum, 9)) * quantum
            if q_t < t:        # float fuzz must never round below t, or a
                q_t += quantum  # delivery could be polled before it's due
            t = q_t
        cur = self._pending_wake.get(target)
        if cur is not None and cur <= t:
            return
        self._pending_wake[target] = t
        self.schedule(t, "wake", target)

    def next_time(self) -> float | None:
        return self._heap[0][0] if self._heap else None

    def pop_due(self, now: float) -> list:
        out = []
        while self._heap and self._heap[0][0] <= now:
            ev = heapq.heappop(self._heap)
            self.processed += 1
            if ev[2] == "wake" and self._pending_wake.get(ev[3]) == ev[0]:
                del self._pending_wake[ev[3]]
            out.append(ev)
        return out


@dataclass
class InstanceType:
    """Per-kind overrides of the scalar SimParams fields (None -> inherit)."""
    creation_delay: float | None = None
    cost_per_instance_second: float | None = None
    client_workers: int | None = None
    preemptible: bool = True            # spot waves only hit preemptible kinds
    min_billing_s: float | None = None  # minimum billed commitment


@dataclass
class SimParams:
    creation_delay: float = 2.0        # VM boot time
    min_create_interval: float = 0.5   # platform rate limit
    client_workers: int = 4            # CPUs per client instance
    latency: float = 0.01              # message latency
    dt: float = 0.05                   # step size (mode="fixed" only)
    cost_per_instance_second: float = 1.0
    min_billing_s: float = 0.0         # per-instance minimum billed seconds
    #   (clouds bill a minimum commitment per started instance; makes
    #   over-provisioning visible to the cost account)
    mode: str = "events"               # "events" | "fixed" (legacy polling)
    latency_jitter: float = 0.0        # U[0, jitter) extra delay per message
    seed: int = 0                      # RNG seed (jitter + spot waves)
    wake_quantum: float = 0.05         # server wake coalescing granularity
    client_health_interval: float = 1.0   # heartbeat cadence of sim clients
    ready_poll: bool = True            # servers skip endpoints w/o deliveries
    instance_types: dict = field(default_factory=dict)  # kind -> InstanceType
    # chaos/trace layer (see repro.core.trace and SimNetwork):
    record_trace: bool = False         # collect a replayable timing trace
    trace: object = None               # Trace | dict | path: replay mode —
    #   message delays, creation delays, task runtimes and preemptions come
    #   from the recorded trace instead of latency/jitter/RNG parameters


class SimEngine(AbstractEngine):
    def __init__(self, clock: Clock, params: SimParams | None = None, *,
                 loop: EventLoop | None = None, servers_target=SERVERS):
        self.clock = clock
        self.params = params or SimParams()
        # multi-scheduler runs (ShardedSimCluster) share ONE event loop
        # across K engines; each engine then wakes its own servers under
        # a distinct target (e.g. ``(SERVERS, shard_id)``) so the heap
        # routes server wakes to the right shard
        self.servers_target = servers_target
        if loop is None:
            self.loop = EventLoop(clock)
            self.loop.enabled = self.params.mode != "fixed"
        else:
            self.loop = loop
        self.rng = random.Random(self.params.seed)
        # fault/timing plane shared by every wire of this engine
        self.network = transport.SimNetwork(clock)
        if self.params.record_trace:
            self.network.recorder = TraceRecorder()
        if self.params.trace is not None:
            self.network.replayer = TraceReplayer(as_trace(self.params.trace))
        self.pending: dict[str, PendingInstance] = {}
        self.nodes: dict[str, object] = {}      # name -> Client|Server
        self.server_nodes: dict[str, Server] = {}   # subset of nodes
        self.alive: dict[str, bool] = {}
        self._instances: dict[str, float] = {}  # name -> created_at (billing)
        self._stopped_at: dict[str, float] = {}
        self._rates: dict[str, float] = {}      # name -> $/instance-second
        self._kinds: dict[str, str] = {}        # name -> kind (persistent
        #   registry: entries survive termination so instance_kind and
        #   billing_records stay answerable for closed instances)
        # ready-set polling: server-side wire -> earliest pending delivery
        # (servers skip draining endpoints with nothing due)
        self._wire_ready: dict = {}
        self._boot_eps: dict[str, tuple] = {}   # name -> client-side endpoints
        self._to_create: list = []              # (t, kind, name, payload)
        self._last_create = -1e18
        self._primary_eps: dict[str, transport.SimEndpoint] = {}
        self._backup_eps: dict[str, transport.SimEndpoint] = {}
        self._client_eps: dict[str, tuple] = {}
        # handshake is a control-plane wire: no jitter, so an instance's
        # HANDSHAKE is never observed after protocol messages it precedes.
        # It is labelled for trace replay but exempt from partitions (the
        # public partition API only addresses role/client labels)
        hs_srv, hs_cli = transport.sim_link(
            clock, self.params.latency,
            notify_a=self._notify(self.servers_target),
            label_a="control", label_b="instances", network=self.network)
        self.handshake_recv = hs_srv
        self._handshake_send = hs_cli
        self.cost_log: list = []                # (name, start, end, rate)
        # SimCluster clears this when the server config disables backups:
        # without a backup server the two-copy wires are never drained, so
        # minting them only doubles every client send
        self.backup_links = True
        if not self.params.ready_poll:
            # shadow the methods: servers fall back to draining everything
            self.ready_wires = None
            self.endpoint_drained = None

    # ------------------------------------------------------------------
    # event plumbing
    # ------------------------------------------------------------------
    def _notify(self, target):
        if target is None:
            return None
        quantum = self.params.wake_quantum \
            if target == self.servers_target else 0.0

        def cb(t, _target=target, _q=quantum):
            self.loop.wake(_target, t, _q)
        return cb

    def _link(self, recv_a=None, recv_b=None, label_a=None, label_b=None):
        a, b = transport.sim_link(
            self.clock, self.params.latency,
            jitter=self.params.latency_jitter, rng=self.rng,
            notify_a=self._notify(recv_a), notify_b=self._notify(recv_b),
            label_a=label_a, label_b=label_b, network=self.network)
        if recv_a == self.servers_target:
            self._track_server_wire(a)
        if recv_b == self.servers_target:
            self._track_server_wire(b)
        return a, b

    # ------------------------------------------------------------------
    # ready-set endpoint polling (ROADMAP item): every delivery into a
    # server-side wire records the earliest readable time, so the primary
    # (and backup) skip draining client endpoints with nothing due
    # ------------------------------------------------------------------
    def _track_server_wire(self, ep):
        wire = ep.recv_wire
        base = wire.on_deliver

        def cb(t, _w=wire, _base=base):
            cur = self._wire_ready.get(_w)
            if cur is None or t < cur:
                self._wire_ready[_w] = t
            if _base is not None:
                _base(t)
        wire.on_deliver = cb

    def ready_wires(self, now: float) -> list:
        """Server-side wires with a delivery due at or before ``now``.
        Servers map these back to clients through their own ownership
        table and drain only those endpoints — O(due wires) instead of
        O(clients) per step."""
        return [w for w, t in self._wire_ready.items() if t <= now]

    def endpoint_drained(self, ep) -> None:
        wire = getattr(ep, "recv_wire", None)
        if wire is None:
            return
        nxt = wire.next_delivery()
        if nxt is None:
            self._wire_ready.pop(wire, None)
        else:
            self._wire_ready[wire] = nxt   # future deliveries still queued

    # ------------------------------------------------------------------
    # heterogeneous instance types
    # ------------------------------------------------------------------
    def _type_attr(self, kind: str, attr: str):
        itype = self.params.instance_types.get(kind)
        if itype is not None:
            val = getattr(itype, attr)
            if val is not None:
                return val
        return getattr(self.params, attr)

    def preemptible(self, name: str) -> bool:
        itype = self.params.instance_types.get(self._kinds.get(name, ""))
        return itype.preemptible if itype is not None else True

    # ------------------------------------------------------------------
    def now(self) -> float:
        return self.clock.now()

    def create_instance(self, kind, name, payload=None):
        now = self.now()
        if now - self._last_create < self.params.min_create_interval:
            raise RateLimited()
        self._last_create = now
        delay = self._type_attr(kind, "creation_delay")
        if self.network.replayer is not None:
            delay = self.network.replayer.creation_delay(name, delay)
        if self.network.recorder is not None:
            self.network.recorder.record_creation(name, delay)
        due = now + delay
        # Register the pending record at *creation request* time, exactly
        # like LocalEngine/GCEEngine do — the server's max_clients gate
        # counts len(engine.pending), so deferring registration to
        # materialization silently over-creates instances while they boot.
        self._kinds[name] = kind
        if kind.startswith("backup"):
            pb_primary, pb_backup = self._link(recv_a=self.servers_target,
                                               recv_b=self.servers_target,
                                               label_a="primary",
                                               label_b="backup")
            self.pending[name] = PendingInstance(
                name, kind, now, primary_side=pb_primary, payload=payload)
            self._boot_eps[name] = (pb_backup,)
        else:
            p_srv, p_cli = self._link(recv_a=self.servers_target,
                                      recv_b=name,
                                      label_a="primary", label_b=name)
            self._primary_eps[name] = p_srv
            if self.backup_links:
                b_srv, b_cli = self._link(recv_a=self.servers_target,
                                          recv_b=name,
                                          label_a="backup", label_b=name)
                self._backup_eps[name] = b_srv
            else:
                b_srv = b_cli = None
            self.pending[name] = PendingInstance(
                name, kind, now, primary_side=p_srv, backup_side=b_srv)
            self._boot_eps[name] = (p_cli, b_cli)
        heapq.heappush(self._to_create, (due, kind, name, payload))
        self.loop.schedule(due, "materialize")

    def terminate_instance(self, name):
        self.nodes.pop(name, None)
        self.server_nodes.pop(name, None)
        self.alive.pop(name, None)
        self.pending.pop(name, None)
        self._boot_eps.pop(name, None)
        # _kinds is deliberately retained: the registry keeps answering
        # instance_kind / billing_records for terminated instances
        for ep in (self._primary_eps.pop(name, None),
                   self._backup_eps.pop(name, None)):
            if ep is not None:
                self._wire_ready.pop(getattr(ep, "recv_wire", None), None)
        if name in self._instances:
            rate = self._rates.pop(name, self.params.cost_per_instance_second)
            start = self._instances.pop(name)
            min_bill = self._type_attr(self._kinds.get(name, "client"),
                                       "min_billing_s")
            end = max(self.now(), start + min_bill)
            self.cost_log.append((name, start, end, rate))

    def list_instances(self):
        return list(self._instances)

    def primary_endpoints(self, name):
        return self._primary_eps.get(name)

    def backup_endpoint(self, name):
        return self._backup_eps.get(name)

    def rotate_client_channels(self, name):
        """Takeover bookkeeping: the backup-turned-primary now serves the
        client over the old *backup* link, so that link becomes the
        client's primary link and a fresh backup link is minted for the
        next backup server.  Returns the client-side end of the fresh link
        (shipped to the client inside SWAP_QUEUES).  Without this, a
        post-takeover backup would attach to the same endpoint the acting
        primary polls and steal its client messages."""
        old_p = self._primary_eps.get(name)
        if old_p is not None:
            # the dead primary's wire is abandoned: purge its ready mark
            # so ready_wires() stops returning it forever
            self._wire_ready.pop(getattr(old_p, "recv_wire", None), None)
        old_b = self._backup_eps.get(name)
        if old_b is not None:
            self._primary_eps[name] = old_b
            # the promoted link now carries primary traffic: relabel its
            # routes so partitions/traces keyed by role follow the role
            old_b.send_wire.route = ("primary", name)
            old_b.recv_wire.route = (name, "primary")
        b_srv, b_cli = self._link(recv_a=self.servers_target, recv_b=name,
                                  label_a="backup", label_b=name)
        self._backup_eps[name] = b_srv
        return b_cli

    # ------------------------------------------------------------------
    # fault injection: first-class network partitions (per-link,
    # per-direction) — deliveries on dark routes are silently dropped
    # ------------------------------------------------------------------
    def partition(self, a: str, b: str, direction: str = "both",
                  until: float | None = None):
        """Drop messages on the a<->b link.  ``a``/``b`` are role or
        instance labels ("primary", "backup", or a client name);
        ``direction`` is "both", "a2b" (a's sends to b are lost) or
        "b2a".  ``until`` auto-heals the partition at that virtual time
        (a server wake is scheduled so liveness reacts promptly)."""
        if direction not in ("both", "a2b", "b2a"):
            raise ValueError(f"bad partition direction: {direction!r}")
        if direction in ("both", "a2b"):
            self.network.partition(a, b, until)
        if direction in ("both", "b2a"):
            self.network.partition(b, a, until)
        if until is not None:
            self.loop.wake(self.servers_target, until)

    def heal(self, a: str, b: str):
        """Remove both directions of an a<->b partition."""
        self.network.heal(a, b)
        self.network.heal(b, a)
        self.loop.wake(self.servers_target, self.now())

    def link_down(self, a: str, b: str) -> bool:
        """True while either direction of the a<->b link is dark (server
        shells poll this as their partition detector — the simulator
        stand-in for the connection errors a real transport surfaces)."""
        return self.network.link_down(a, b)

    def faults_possible(self) -> bool:
        """Cheap fast-path guard for the shells' link sweeps: False means
        no partition was ever injected (or all were healed), so a
        per-client ``link_down`` sweep cannot find anything.  O(1) and
        conservative (may return True briefly after lazy auto-heal)."""
        return self.network.any_partitions()

    # ------------------------------------------------------------------
    def kill(self, name):
        """Crash an instance: it stops stepping and its links go dark, but
        it remains listed (the VM is still up and billing)."""
        if self.network.recorder is not None and self.alive.get(name, False):
            self.network.recorder.record_preemption(self.now(), name)
        self.alive[name] = False
        node = self.nodes.get(name)
        if node is not None and isinstance(node, Client):
            for ep in (node.primary, node.backup):
                if isinstance(ep, transport.SimEndpoint):
                    ep.brk()

    def materialize_due(self):
        now = self.now()
        while self._to_create and self._to_create[0][0] <= now:
            _, kind, name, payload = heapq.heappop(self._to_create)
            boot = self._boot_eps.pop(name, None)
            if boot is None or name not in self.pending:
                continue   # creation was cancelled while booting
            self._instances[name] = now
            self._rates[name] = self._type_attr(
                kind, "cost_per_instance_second")
            self.alive[name] = True
            if kind.startswith("backup"):
                (pb_backup,) = boot
                srv = Server.from_snapshot(payload, self, name)
                srv.backup_bootstrap(primary_endpoint=pb_backup,
                                     handshake_send=self._handshake_send)
                self.nodes[name] = srv
                self.server_nodes[name] = srv
                self.loop.wake(self.servers_target, now)
            else:
                p_cli, b_cli = boot
                pool = SimWorkerPool(
                    self._type_attr(kind, "client_workers"), self.clock,
                    notify=self._notify(name),
                    runtime_fn=self._task_runtime)
                client = Client(name, p_cli, b_cli, pool,
                                clock=self.clock.now,
                                handshake=self._handshake_send,
                                health_interval=self.params
                                .client_health_interval)
                self.nodes[name] = client
                self.loop.wake(name, now)

    def _task_runtime(self, tid, default: float) -> float:
        """Trace hook: worker pools resolve each task's virtual runtime
        here, so a loaded trace overrides scripted durations and a
        recorder captures the ones actually used."""
        d = default
        if self.network.replayer is not None:
            d = self.network.replayer.runtime(tid, d)
        if self.network.recorder is not None:
            self.network.recorder.record_runtime(tid, d)
        return d

    def _min_billed_end(self, name: str, start: float, now: float) -> float:
        min_bill = self._type_attr(self._kinds.get(name, "client"),
                                   "min_billing_s")
        return max(now, start + min_bill)

    def total_cost(self) -> float:
        now = self.now()
        base = self.params.cost_per_instance_second
        cost = sum((end - start) * rate
                   for _, start, end, rate in self.cost_log)
        cost += sum((self._min_billed_end(name, start, now) - start)
                    * self._rates.get(name, base)
                    for name, start in self._instances.items())
        return cost

    def cost_rate(self, kind: str) -> float:
        return self._type_attr(kind, "cost_per_instance_second")

    def billing_records(self):
        """Exact virtual-clock billing intervals for the CostMeter.  Open
        instances carry their minimum-billing commitment as ``min_end``
        so budget projections see spend that is locked in but not yet
        elapsed (closed intervals were already floored at termination)."""
        base = self.params.cost_per_instance_second
        recs = [(name, self._kinds.get(name, "client"), rate, start, end)
                for name, start, end, rate in self.cost_log]
        for name, start in self._instances.items():
            kind = self._kinds.get(name, "client")
            min_bill = self._type_attr(kind, "min_billing_s")
            recs.append((name, kind, self._rates.get(name, base), start,
                         None, start + min_bill))
        return recs


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------
class SimCluster:
    """Primary server + engine on a shared virtual clock, with an event
    script: ``at(t, fn)`` callbacks fire once when the clock passes t."""

    def __init__(self, tasks, config: ServerConfig | None = None,
                 params: SimParams | None = None, _internal: bool = False):
        if not _internal:
            warnings.warn(
                "hand-wiring SimCluster(tasks, config, params) is "
                "deprecated; use repro.core.Experiment(tasks, engine='sim', "
                "sim=...) — chaos scripting stays available via the run "
                "handle's .cluster", DeprecationWarning, stacklevel=2)
        self.clock = Clock()
        self.params = params or SimParams()
        self.engine = SimEngine(self.clock, self.params)
        self.loop = self.engine.loop
        self.server = Server(tasks, self.engine, config, _internal=True)
        self.engine.backup_links = self.server.config.use_backup
        self.engine._instances["primary"] = 0.0
        self.engine._kinds["primary"] = "server"
        self.engine._rates["primary"] = self.engine.cost_rate("server")
        self.engine.alive["primary"] = True
        self._script: list = []   # (t, fn) sorted
        self._primary_killed = False
        self.loop.wake(SERVERS, 0.0)
        # trace replay: re-inject the recorded preemptions as scripted
        # kills (the recording run's spot waves / scripted kills are part
        # of the trace, so the replay run must not re-script them)
        if self.engine.network.replayer is not None:
            for t, name in self.engine.network.replayer.preemptions():
                if name == "primary":
                    self.at(t, lambda c: c.kill_primary())
                else:
                    self.at(t, lambda c, _n=name: c.engine.kill(_n))

    def at(self, t: float, fn):
        self._script.append((t, fn))
        self._script.sort(key=lambda x: x[0])
        self.loop.schedule(t, "script")

    # ------------------------------------------------------------------
    # chaos scripting: network partitions (see SimEngine.partition)
    # ------------------------------------------------------------------
    def partition(self, a: str, b: str, direction: str = "both",
                  at: float | None = None, until: float | None = None):
        """Partition the a<->b link, immediately or at virtual time
        ``at``; ``until`` auto-heals."""
        if at is None:
            self.engine.partition(a, b, direction, until)
        else:
            self.at(at, lambda c: c.engine.partition(a, b, direction, until))

    def heal(self, a: str, b: str, at: float | None = None):
        if at is None:
            self.engine.heal(a, b)
        else:
            self.at(at, lambda c: c.engine.heal(a, b))

    # ------------------------------------------------------------------
    # trace record/replay
    # ------------------------------------------------------------------
    def trace(self):
        """The recorded Trace of this run (requires
        ``SimParams(record_trace=True)``)."""
        rec = self.engine.network.recorder
        if rec is None:
            raise ValueError("run with SimParams(record_trace=True) "
                             "to record a trace")
        return rec.build(meta={"makespan_s": self.clock.now(),
                               "seed": self.params.seed})

    def write_trace(self, path: str):
        self.trace().write(path)

    def spot_wave(self, t: float, fraction: float):
        """Script a spot-preemption wave: at time ``t`` kill ``fraction`` of
        the alive preemptible client instances (engine RNG, seeded)."""
        def fn(c):
            eng = c.engine
            victims = [name for name, node in eng.nodes.items()
                       if isinstance(node, Client)
                       and eng.alive.get(name, False)
                       and eng.preemptible(name)]
            k = min(int(round(len(victims) * fraction)), len(victims))
            for name in eng.rng.sample(victims, k):
                eng.kill(name)
        self.at(t, fn)

    def kill_primary(self):
        rec = self.engine.network.recorder
        if rec is not None and self.engine.alive.get("primary", False):
            rec.record_preemption(self.clock.now(), "primary")
        self.engine.alive["primary"] = False
        self._primary_killed = True

    def clients(self) -> list[Client]:
        return [n for n in self.engine.nodes.values()
                if isinstance(n, Client)]

    def servers(self) -> list[Server]:
        """Alive server nodes, keyed by the engine registry (a node's own
        ``name`` attribute becomes "primary*" after takeover and must not
        be used for liveness lookups)."""
        out = []
        if self.engine.alive.get("primary", False):
            out.append(self.server)
        out += [n for key, n in self.engine.server_nodes.items()
                if self.engine.alive.get(key, False)]
        return out

    def acting_primary(self) -> Server | None:
        for key, n in self.engine.server_nodes.items():
            if n.role == "primary" and self.engine.alive.get(key, False):
                return n
        if self.engine.alive.get("primary", False):
            return self.server
        return None

    # ------------------------------------------------------------------
    # discrete-event stepping
    # ------------------------------------------------------------------
    def step(self):
        if self.params.mode == "fixed":
            self._step_fixed()
        else:
            self._step_events()

    def _step_events(self):
        """Jump the clock to the next scheduled event and process every
        event due at that instant, stepping only the nodes concerned."""
        t = self.loop.next_time()
        if t is None:
            # quiescent (nothing scheduled): nudge time forward so callers
            # looping on step() still make progress
            self.clock.advance(self.params.dt)
        else:
            self.clock.advance_to(t)
        now = self.clock.now()
        events = self.loop.pop_due(now)

        # script callbacks fire first (matches the fixed-step loop order)
        while self._script and self._script[0][0] <= now:
            _, fn = self._script.pop(0)
            fn(self)
        self.engine.materialize_due()

        wake_servers = False
        wake_clients: list = []
        for _, _, kind, data in events:
            if kind == "wake":
                if data == SERVERS:
                    wake_servers = True
                else:
                    wake_clients.append(data)
            elif kind in ("script", "materialize"):
                # handled above; a script may also demand a server step
                # (e.g. a kill that the survivors must react to)
                wake_servers = True

        if wake_servers:
            self._step_servers(now)
        for name in wake_clients:
            node = self.engine.nodes.get(name)
            if node is None or not self.engine.alive.get(name, False):
                continue
            node.step()
            self.loop.wake(name, node.next_wake(now))

    def _step_servers(self, now: float):
        nxt = None
        for srv in self.servers():
            srv.step()
            w = srv.next_wake(now)
            nxt = w if nxt is None else min(nxt, w)
        if nxt is not None:
            # intrinsic wakes (heartbeats, creation backoffs) stay exact;
            # only message-delivery wakes are coalesced by wake_quantum
            self.loop.wake(SERVERS, nxt)

    # ------------------------------------------------------------------
    # legacy fixed-dt stepping (semantic reference; O(T/dt * nodes))
    # ------------------------------------------------------------------
    def _step_fixed(self):
        now = self.clock.now()
        while self._script and self._script[0][0] <= now:
            _, fn = self._script.pop(0)
            fn(self)
        self.engine.materialize_due()
        if self.engine.alive.get("primary", False):
            self.server.step()
        for name, node in list(self.engine.nodes.items()):
            if not self.engine.alive.get(name, False):
                continue
            node.step()
        self.clock.advance(self.params.dt)

    def steps(self, until: float = 1e9, max_steps: int = 200_000,
              stop_when_done: bool = True):
        """Generator form of the drive loop: yields after every step —
        ``None`` while running, the done acting primary on the final
        yield (so streaming consumers can observe each step).  Raises
        TimeoutError when the bounds expire with no done primary."""
        events_mode = self.params.mode != "fixed"
        for _ in range(max_steps):
            if events_mode:
                nt = self.loop.next_time()
                if nt is None or nt >= until:
                    break
            elif self.clock.now() >= until:
                break
            self.step()
            if stop_when_done:
                prim = self._done_primary()
                if prim is not None:
                    yield prim
                    return
            yield None
        prim = self._done_primary()
        if prim is not None:
            yield prim
            return
        raise TimeoutError(
            f"simulation did not finish by t={self.clock.now():.1f}")

    def run(self, until: float = 1e9, max_steps: int = 200_000,
            stop_when_done: bool = True) -> Server:
        """Steps until some acting primary reports done. Returns it."""
        for prim in self.steps(until, max_steps, stop_when_done):
            if prim is not None:
                return prim

    def _done_primary(self):
        if self.engine.alive.get("primary", False):
            return self.server if self.server.done else None
        for name, node in self.engine.server_nodes.items():
            if node.role == "primary" \
                    and self.engine.alive.get(name, False) and node.done:
                return node
        return None


# ---------------------------------------------------------------------------
# sharded harness: K primary(+backup) scheduler pairs on ONE event loop
# ---------------------------------------------------------------------------
class ShardedSimCluster:
    """K independent scheduler shards sharing one virtual clock and one
    event heap.  Each shard is a full ``SchedulerCore``/``Server`` stack
    on its own ``SimEngine`` (own network, own fleet, instance names
    namespaced ``s<k>-``), woken under the per-shard target
    ``(SERVERS, k)``; the :class:`repro.core.shard.ShardCoordinator`
    gossips every shard's ``MinHardSet`` frontier to the others after
    each server round, so the domino rule prunes globally exactly as a
    single scheduler would."""

    def __init__(self, tasks, config: ServerConfig | None = None,
                 params: SimParams | None = None, n_shards: int = 2,
                 _internal: bool = False, _resume: dict | None = None):
        if not _internal:
            warnings.warn(
                "hand-wiring ShardedSimCluster is deprecated; use "
                "repro.core.Experiment(tasks, engine='sim', shards=K)",
                DeprecationWarning, stacklevel=2)
        self.params = params or SimParams()
        if self.params.mode == "fixed":
            raise ValueError("sharded simulation requires the event "
                             "engine (SimParams.mode='events')")
        self.clock = Clock()
        self.loop = EventLoop(self.clock)
        self.tasks = list(tasks)
        base = config or ServerConfig()
        if base.min_group_size > 0:
            raise ValueError(
                "min_group_size retention cannot run per shard (a group "
                "split across shards would be dropped wrongly)")
        if _resume is not None:
            n_shards = len(_resume["shards"])
        self.n_shards = int(n_shards)
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if _resume is not None:
            self.shard_indices = [list(ix) for ix in _resume["indices"]]
            self.coordinator = ShardCoordinator.restore(
                _resume["coordinator"])
        else:
            self.shard_indices = partition_tasks(self.tasks, self.n_shards)
            self.coordinator = ShardCoordinator(self.n_shards)
        self.engines: list[SimEngine] = []
        self.servers: list[Server] = []   # the initial primaries (a
        #   shard's *acting* primary moves on takeover — use
        #   acting_primaries() for live lookups)
        self._script: list = []           # (t, fn) sorted
        self._home: dict = {}             # client name -> engine (lazy)
        for k in range(self.n_shards):
            eng = SimEngine(self.clock, self.params, loop=self.loop,
                            servers_target=(SERVERS, k))
            if _resume is not None:
                srv = Server.resume_primary(_resume["shards"][k], eng)
            else:
                cfg = dataclasses.replace(base, name_prefix=f"s{k}-")
                shard_tasks = [self.tasks[i]
                               for i in self.shard_indices[k]]
                srv = Server(shard_tasks, eng, cfg, _internal=True)
            eng.backup_links = srv.config.use_backup
            eng._instances["primary"] = 0.0
            eng._kinds["primary"] = "server"
            eng._rates["primary"] = eng.cost_rate("server")
            eng.alive["primary"] = True
            self.engines.append(eng)
            self.servers.append(srv)
            self.loop.wake(eng.servers_target, 0.0)

    # ------------------------------------------------------------------
    def at(self, t: float, fn):
        """Script a callback ``fn(cluster)`` at virtual time ``t``."""
        self._script.append((t, fn))
        self._script.sort(key=lambda x: x[0])
        self.loop.schedule(t, "script")

    def clients(self) -> list[Client]:
        return [n for eng in self.engines for n in eng.nodes.values()
                if isinstance(n, Client)]

    def shard_servers(self, k: int) -> list[Server]:
        """Alive server nodes of shard ``k`` (initial primary + any
        booted backups/takeover primaries), engine-registry keyed."""
        eng = self.engines[k]
        out = []
        if eng.alive.get("primary", False):
            out.append(self.servers[k])
        out += [n for key, n in eng.server_nodes.items()
                if eng.alive.get(key, False)]
        return out

    def acting_primaries(self) -> dict[int, Server]:
        """shard id -> acting primary, omitting shards mid-takeover."""
        out: dict[int, Server] = {}
        for k, eng in enumerate(self.engines):
            found = None
            for key, n in eng.server_nodes.items():
                if n.role == "primary" and eng.alive.get(key, False):
                    found = n
                    break
            if found is None and eng.alive.get("primary", False):
                found = self.servers[k]
            if found is not None:
                out[k] = found
        return out

    # ------------------------------------------------------------------
    # discrete-event stepping (one heap, K server groups)
    # ------------------------------------------------------------------
    def step(self):
        t = self.loop.next_time()
        if t is None:
            self.clock.advance(self.params.dt)
        else:
            self.clock.advance_to(t)
        now = self.clock.now()
        events = self.loop.pop_due(now)

        while self._script and self._script[0][0] <= now:
            _, fn = self._script.pop(0)
            fn(self)
        for eng in self.engines:
            eng.materialize_due()

        wake_shards: set[int] = set()
        wake_clients: list = []
        for _, _, kind, data in events:
            if kind == "wake":
                if isinstance(data, tuple) and len(data) == 2 \
                        and data[0] == SERVERS:
                    wake_shards.add(data[1])
                else:
                    wake_clients.append(data)
            elif kind in ("script", "materialize"):
                wake_shards.update(range(self.n_shards))

        for k in sorted(wake_shards):
            self._step_shard(k, now)
        if wake_shards:
            # gossip after the server round: publish frontiers that just
            # changed and deliver queued cross-shard prunes promptly
            pump_gossip(self.coordinator, self.acting_primaries())
        for name in wake_clients:
            eng = self._engine_of(name)
            if eng is None:
                continue
            node = eng.nodes.get(name)
            if node is None or not eng.alive.get(name, False):
                continue
            node.step()
            self.loop.wake(name, node.next_wake(now))

    def _step_shard(self, k: int, now: float):
        nxt = None
        for srv in self.shard_servers(k):
            srv.step()
            w = srv.next_wake(now)
            nxt = w if nxt is None else min(nxt, w)
        if nxt is not None:
            self.loop.wake(self.engines[k].servers_target, nxt)

    def _engine_of(self, name) -> SimEngine | None:
        eng = self._home.get(name)
        if eng is not None:
            return eng
        for eng in self.engines:
            if name in eng.nodes or name in eng.pending:
                self._home[name] = eng   # names are never reused, so a
                #   terminated entry just resolves to a dead node (skip)
                return eng
        return None

    # ------------------------------------------------------------------
    def _done_primaries(self) -> dict | None:
        acting = self.acting_primaries()
        if len(acting) == self.n_shards \
                and all(s.done for s in acting.values()):
            return acting
        return None

    def steps(self, until: float = 1e9, max_steps: int = 2_000_000,
              stop_when_done: bool = True):
        """Generator drive loop: yields ``None`` while running and the
        ``{shard: done primary}`` dict on the final yield."""
        for _ in range(max_steps):
            nt = self.loop.next_time()
            if nt is None or nt >= until:
                break
            self.step()
            if stop_when_done:
                acting = self._done_primaries()
                if acting is not None:
                    yield acting
                    return
            yield None
        acting = self._done_primaries()
        if acting is not None:
            yield acting
            return
        raise TimeoutError(
            f"sharded simulation did not finish by t={self.clock.now():.1f}")

    def run(self, until: float = 1e9, max_steps: int = 2_000_000,
            stop_when_done: bool = True) -> dict:
        """Steps until every shard's acting primary is done; returns the
        ``{shard: primary}`` map."""
        for acting in self.steps(until, max_steps, stop_when_done):
            if acting is not None:
                return acting

    def merged_results(self):
        """The per-shard results tables merged back into submission
        order (see :func:`repro.core.shard.merge_results`)."""
        acting = self.acting_primaries()
        tables = [acting[k].final_results if k in acting else None
                  for k in range(self.n_shards)]
        return merge_results(tables, self.shard_indices)

    # ------------------------------------------------------------------
    def serialize_state(self) -> bytes:
        """Snapshot every shard's scheduler core plus the coordinator's
        gossip state — feed to ``Experiment.resume()``."""
        acting = self.acting_primaries()
        missing = [k for k in range(self.n_shards) if k not in acting]
        if missing:
            raise RuntimeError(
                f"shards {missing} have no acting primary (takeover in "
                "flight) — snapshot once a primary is acting")
        return pickle.dumps({
            "version": 1,
            "shards": [acting[k].serialize_state()
                       for k in range(self.n_shards)],
            "indices": [list(ix) for ix in self.shard_indices],
            "coordinator": self.coordinator.snapshot(),
        })


# ---------------------------------------------------------------------------
# scripted tasks for simulation
# ---------------------------------------------------------------------------
class SimTask(AbstractTask):
    """A task with scripted virtual duration; run() returns its fields."""

    def __init__(self, params: tuple, titles: tuple, hardness_values: tuple,
                 sim_duration: float, deadline: float | None = None,
                 result: tuple | None = None,
                 group_titles: tuple | None = None):
        self._params = tuple(params)
        self._titles = tuple(titles)
        self._hard = tuple(hardness_values)
        self.sim_duration = sim_duration
        self._deadline = deadline
        self._result = result if result is not None else (sim_duration,)
        self._group_titles = group_titles

    def parameter_titles(self):
        return self._titles

    def parameters(self):
        return self._params

    def hardness_parameters(self):
        return self._hard

    def result_titles(self):
        return ("value",) * len(self._result) if self._result else ("value",)

    def run(self):
        return self._result

    def timeout(self):
        return self._deadline

    def group_parameter_titles(self):
        if self._group_titles is not None:
            return self._group_titles
        return super().group_parameter_titles()
