"""Deterministic cloud simulator (virtual clock).

The paper's local engine "is actually a simulation of performing the
experiment on the cloud ... a powerful tool to facilitate further
development".  We take that seriously: ``SimEngine`` runs the *same*
Server/Client protocol code as the real engines, but on a virtual clock
with scripted instance-creation delays, rate limits, message latency and
failure injection — so the fault-tolerance protocol (backup mirroring,
takeover, task reassignment, domino effect) is unit-testable and
benchmarkable with exact reproducibility.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

from repro.core import transport
from repro.core.client import Client
from repro.core.engine import AbstractEngine, PendingInstance, RateLimited
from repro.core.messages import Message, MsgType
from repro.core.server import Server, ServerConfig
from repro.core.task import AbstractTask
from repro.core.workerpool import SimWorkerPool


class Clock:
    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


@dataclass
class SimParams:
    creation_delay: float = 2.0        # VM boot time
    min_create_interval: float = 0.5   # platform rate limit
    client_workers: int = 4            # CPUs per client instance
    latency: float = 0.01              # message latency
    dt: float = 0.05                   # step size
    cost_per_instance_second: float = 1.0


class SimEngine(AbstractEngine):
    def __init__(self, clock: Clock, params: SimParams | None = None):
        self.clock = clock
        self.params = params or SimParams()
        self.pending: dict[str, PendingInstance] = {}
        self.nodes: dict[str, object] = {}      # name -> Client|Server
        self.alive: dict[str, bool] = {}
        self._instances: dict[str, float] = {}  # name -> created_at (billing)
        self._stopped_at: dict[str, float] = {}
        self._to_create: list = []              # (t, kind, name, payload)
        self._last_create = -1e18
        self._primary_eps: dict[str, transport.SimEndpoint] = {}
        self._backup_eps: dict[str, transport.SimEndpoint] = {}
        self._client_eps: dict[str, tuple] = {}
        hs_srv, hs_cli = transport.sim_link(clock, self.params.latency)
        self.handshake_recv = hs_srv
        self._handshake_send = hs_cli
        self.cost_log: list = []                # (name, start, end)

    # ------------------------------------------------------------------
    def now(self) -> float:
        return self.clock.now()

    def create_instance(self, kind, name, payload=None):
        now = self.now()
        if now - self._last_create < self.params.min_create_interval:
            raise RateLimited()
        self._last_create = now
        heapq.heappush(self._to_create,
                       (now + self.params.creation_delay, kind, name, payload))

    def terminate_instance(self, name):
        self.nodes.pop(name, None)
        self.alive.pop(name, None)
        self.pending.pop(name, None)
        if name in self._instances:
            self.cost_log.append((name, self._instances.pop(name), self.now()))

    def list_instances(self):
        return list(self._instances)

    def primary_endpoints(self, name):
        return self._primary_eps.get(name)

    def backup_endpoint(self, name):
        return self._backup_eps.get(name)

    # ------------------------------------------------------------------
    def kill(self, name):
        """Crash an instance: it stops stepping and its links go dark, but
        it remains listed (the VM is still up and billing)."""
        self.alive[name] = False
        node = self.nodes.get(name)
        if node is not None and isinstance(node, Client):
            for ep in (node.primary, node.backup):
                if isinstance(ep, transport.SimEndpoint):
                    ep.brk()

    def materialize_due(self):
        now = self.now()
        while self._to_create and self._to_create[0][0] <= now:
            _, kind, name, payload = heapq.heappop(self._to_create)
            if kind == "client":
                p_srv, p_cli = transport.sim_link(self.clock,
                                                  self.params.latency)
                b_srv, b_cli = transport.sim_link(self.clock,
                                                  self.params.latency)
                self._primary_eps[name] = p_srv
                self._backup_eps[name] = b_srv
                pool = SimWorkerPool(self.params.client_workers, self.clock)
                client = Client(name, p_cli, b_cli, pool,
                                clock=self.clock.now,
                                handshake=self._handshake_send)
                self.nodes[name] = client
                self.alive[name] = True
                self._instances[name] = now
                self.pending[name] = PendingInstance(
                    name, kind, now, primary_side=p_srv, backup_side=b_srv)
            elif kind == "backup":
                pb_primary, pb_backup = transport.sim_link(
                    self.clock, self.params.latency)
                srv = Server.from_snapshot(payload, self, name)
                srv.backup_bootstrap(primary_endpoint=pb_backup,
                                     handshake_send=self._handshake_send)
                self.nodes[name] = srv
                self.alive[name] = True
                self._instances[name] = now
                self.pending[name] = PendingInstance(
                    name, kind, now, primary_side=pb_primary)

    def total_cost(self) -> float:
        now = self.now()
        cost = sum(end - start for _, start, end in self.cost_log)
        cost += sum(now - start for start in self._instances.values())
        return cost * self.params.cost_per_instance_second


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------
class SimCluster:
    """Primary server + engine on a shared virtual clock, with an event
    script: ``at(t, fn)`` callbacks fire once when the clock passes t."""

    def __init__(self, tasks, config: ServerConfig | None = None,
                 params: SimParams | None = None):
        self.clock = Clock()
        self.params = params or SimParams()
        self.engine = SimEngine(self.clock, self.params)
        self.server = Server(tasks, self.engine, config)
        self.engine._instances["primary"] = 0.0
        self.engine.alive["primary"] = True
        self._script: list = []   # (t, fn) sorted
        self._primary_killed = False

    def at(self, t: float, fn):
        self._script.append((t, fn))
        self._script.sort(key=lambda x: x[0])

    def kill_primary(self):
        self.engine.alive["primary"] = False
        self._primary_killed = True

    def clients(self) -> list[Client]:
        return [n for n in self.engine.nodes.values()
                if isinstance(n, Client)]

    def servers(self) -> list[Server]:
        out = []
        if self.engine.alive.get("primary", False):
            out.append(self.server)
        out += [n for n in self.engine.nodes.values()
                if isinstance(n, Server) and self.engine.alive.get(n.name if n.name in self.engine.alive else "", True)]
        return out

    def acting_primary(self) -> Server | None:
        for n in self.engine.nodes.values():
            if isinstance(n, Server) and n.role == "primary" \
                    and self.engine.alive.get(_node_name(self.engine, n), True):
                return n
        if self.engine.alive.get("primary", False):
            return self.server
        return None

    def step(self):
        now = self.clock.now()
        while self._script and self._script[0][0] <= now:
            _, fn = self._script.pop(0)
            fn(self)
        self.engine.materialize_due()
        if self.engine.alive.get("primary", False):
            self.server.step()
        for name, node in list(self.engine.nodes.items()):
            if not self.engine.alive.get(name, False):
                continue
            node.step()
        self.clock.advance(self.params.dt)

    def run(self, until: float = 1e9, max_steps: int = 200_000,
            stop_when_done: bool = True) -> Server:
        """Steps until some acting primary reports done. Returns it."""
        for _ in range(max_steps):
            if self.clock.now() >= until:
                break
            self.step()
            if stop_when_done:
                prim = self._done_primary()
                if prim is not None:
                    return prim
        prim = self._done_primary()
        if prim is not None:
            return prim
        raise TimeoutError(
            f"simulation did not finish by t={self.clock.now():.1f}")

    def _done_primary(self):
        if self.engine.alive.get("primary", False) and self.server.done:
            return self.server
        for name, node in self.engine.nodes.items():
            if isinstance(node, Server) and node.role == "primary" \
                    and self.engine.alive.get(name, False) and node.done:
                return node
        return None


def _node_name(engine, node):
    for k, v in engine.nodes.items():
        if v is node:
            return k
    return ""


# ---------------------------------------------------------------------------
# scripted tasks for simulation
# ---------------------------------------------------------------------------
class SimTask(AbstractTask):
    """A task with scripted virtual duration; run() returns its fields."""

    def __init__(self, params: tuple, titles: tuple, hardness_values: tuple,
                 sim_duration: float, deadline: float | None = None,
                 result: tuple | None = None,
                 group_titles: tuple | None = None):
        self._params = tuple(params)
        self._titles = tuple(titles)
        self._hard = tuple(hardness_values)
        self.sim_duration = sim_duration
        self._deadline = deadline
        self._result = result if result is not None else (sim_duration,)
        self._group_titles = group_titles

    def parameter_titles(self):
        return self._titles

    def parameters(self):
        return self._params

    def hardness_parameters(self):
        return self._hard

    def result_titles(self):
        return ("value",) * len(self._result) if self._result else ("value",)

    def run(self):
        return self._result

    def timeout(self):
        return self._deadline

    def group_parameter_titles(self):
        if self._group_titles is not None:
            return self._group_titles
        return super().group_parameter_titles()
