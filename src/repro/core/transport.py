"""Transport abstraction under the server/client protocol.

The paper uses two-way SyncManager queues; we keep that for the local
engine (``MPTransport``) and add a deterministic in-memory transport for
the simulator (``SimTransport``, driven by a virtual clock with optional
latency and scripted link failures).  Server/client code only ever sees
``Channel`` objects, so the *same* protocol code runs under both.
"""
from __future__ import annotations

import collections
import queue as _queue


class Channel:
    """One direction of a two-way link."""

    def send(self, msg) -> None:
        raise NotImplementedError

    def poll(self):
        """Non-blocking receive; returns a Message or None."""
        raise NotImplementedError

    def drain(self, limit: int = 1000) -> list:
        out = []
        for _ in range(limit):
            m = self.poll()
            if m is None:
                break
            out.append(m)
        return out


class Endpoint(Channel):
    """A two-way channel end (send one way, poll the other)."""


# ---------------------------------------------------------------------------
# multiprocessing transport (LocalEngine)
# ---------------------------------------------------------------------------
class MPChannel(Channel):
    def __init__(self, send_q, recv_q):
        self._send = send_q
        self._recv = recv_q

    def send(self, msg):
        self._send.put(msg)

    def poll(self):
        try:
            return self._recv.get_nowait()
        except (_queue.Empty, OSError, EOFError):
            return None


def mp_pipe(manager):
    """Two-way channel pair over a multiprocessing.Manager."""
    q1, q2 = manager.Queue(), manager.Queue()
    return MPChannel(q1, q2), MPChannel(q2, q1)


# ---------------------------------------------------------------------------
# simulated network fault/timing plane (SimEngine)
# ---------------------------------------------------------------------------
class SimNetwork:
    """Shared per-route state for every ``SimWire`` of one engine: the
    partition table (fault injection) and the trace record/replay hooks.

    A *route* is a directed label pair ``(src, dst)``; wires carry their
    route and consult this object on every ``put``.  A dark route drops
    the delivery silently — like a real one-way link loss, the sender
    gets no error and the receiver no event.  Partitions optionally
    auto-heal at ``until`` (lazily: the first query at or past the
    deadline removes the entry, so both the event-driven and the legacy
    fixed-dt loop agree on when a route is dark)."""

    def __init__(self, clock):
        self._clock = clock
        self._dark: dict[tuple, float | None] = {}  # route -> until | None
        self.recorder = None            # TraceRecorder (optional)
        self.replayer = None            # TraceReplayer (optional)
        self.messages_sent = 0          # deliveries accepted onto wires
        #   (benchmark metric: counts every non-dropped put)

    # -- partitions -----------------------------------------------------
    def partition(self, src: str, dst: str, until: float | None = None):
        self._dark[(src, dst)] = until

    def heal(self, src: str, dst: str):
        self._dark.pop((src, dst), None)

    def is_dark(self, route) -> bool:
        if not self._dark:
            return False
        until = self._dark.get(route, "missing")
        if until == "missing":
            return False
        if until is not None and self._clock.now() >= until:
            del self._dark[route]       # lazy auto-heal
            return False
        return True

    def link_down(self, a: str, b: str) -> bool:
        """True when either direction of the a<->b link is dark."""
        return self.is_dark((a, b)) or self.is_dark((b, a))

    def dark_routes(self) -> list:
        return [r for r in list(self._dark) if self.is_dark(r)]

    def any_partitions(self) -> bool:
        """True while any route *might* be dark.  Conservative: an
        expired auto-heal entry counts until a query lazily purges it —
        callers use this as a cheap fast-path guard (skip the per-link
        sweep when no partition was ever injected), never as a per-route
        verdict."""
        return bool(self._dark)

    # -- trace hooks ----------------------------------------------------
    def delay(self, route, default: float) -> float:
        """Per-message delay for ``route``: replayed from a trace when one
        is loaded, otherwise ``default`` (latency + jitter); recorded when
        a recorder is attached."""
        d = default
        if self.replayer is not None and route is not None:
            d = self.replayer.next_delay(route, default)
        if self.recorder is not None and route is not None:
            self.recorder.record_delay(route, d)
        return d


# ---------------------------------------------------------------------------
# simulated transport (SimEngine)
# ---------------------------------------------------------------------------
class SimWire:
    """One-directional wire with latency on a virtual clock.

    ``on_deliver`` (optional) is called with the delivery timestamp of every
    accepted message — the discrete-event engine uses it to wake the
    receiving node exactly when the message becomes readable, instead of
    polling every ``dt``.  ``jitter`` adds U[0, jitter) seconds per message
    from a seeded ``rng`` (delivery order within a wire stays FIFO: a
    message is never readable before its predecessors).  ``route`` labels
    the wire's direction ``(src, dst)`` and ``network`` (a ``SimNetwork``)
    supplies fault injection (dark routes drop deliveries) and trace
    record/replay of per-message delays."""

    def __init__(self, clock, latency: float = 0.0, jitter: float = 0.0,
                 rng=None, on_deliver=None, route=None, network=None):
        self._clock = clock
        self.latency = latency
        self.jitter = jitter
        self._rng = rng
        self._q = collections.deque()   # (deliver_at, msg)
        self.broken = False             # scripted link failure
        self.on_deliver = on_deliver
        self.route = route
        self.network = network

    def put(self, msg):
        if self.broken:
            return  # dropped, like a dead instance's socket
        net = self.network
        # fast paths: skip the partition/trace hooks entirely while no
        # partition was ever injected and no trace is attached — put() is
        # the hottest call of a fleet-scale run (one per message)
        if net is not None and self.route is not None and net._dark \
                and net.is_dark(self.route):
            return  # partitioned: silently dropped, never deferred
        delay = self.latency
        if self.jitter > 0.0 and self._rng is not None:
            delay += self._rng.uniform(0.0, self.jitter)
        if net is not None:
            if net.recorder is not None or net.replayer is not None:
                delay = net.delay(self.route, delay)
            net.messages_sent += 1
        deliver_at = self._clock.now() + delay
        if self._q and self._q[-1][0] > deliver_at:
            deliver_at = self._q[-1][0]   # FIFO: never overtake
        self._q.append((deliver_at, msg))
        if self.on_deliver is not None:
            self.on_deliver(deliver_at)

    def get(self):
        if self._q and self._q[0][0] <= self._clock.now():
            return self._q.popleft()[1]
        return None

    def next_delivery(self) -> float | None:
        """Delivery time of the oldest queued message (None when empty) —
        lets the engine's ready-set tracking skip drained wires."""
        return self._q[0][0] if self._q else None


class SimEndpoint(Endpoint):
    def __init__(self, send_wire: SimWire, recv_wire: SimWire):
        self._send = send_wire
        self._recv = recv_wire

    @property
    def recv_wire(self) -> SimWire:
        return self._recv

    @property
    def send_wire(self) -> SimWire:
        return self._send

    def send(self, msg):
        self._send.put(msg)

    def poll(self):
        return self._recv.get()

    def brk(self):
        self._send.broken = True
        self._recv.broken = True


def sim_link(clock, latency: float = 0.0, jitter: float = 0.0, rng=None,
             notify_a=None, notify_b=None, label_a=None, label_b=None,
             network=None):
    """Returns (endpoint_a, endpoint_b) — a two-way simulated link.

    ``notify_a``/``notify_b`` are delivery callbacks for messages *received*
    by endpoint a / endpoint b respectively (wire direction b->a feeds a).
    ``label_a``/``label_b`` name the two ends for the fault/trace plane:
    the a->b wire gets route ``(label_a, label_b)`` and vice versa."""
    route_ab = route_ba = None
    if label_a is not None and label_b is not None:
        route_ab = (label_a, label_b)
        route_ba = (label_b, label_a)
    ab = SimWire(clock, latency, jitter, rng, on_deliver=notify_b,
                 route=route_ab, network=network)
    ba = SimWire(clock, latency, jitter, rng, on_deliver=notify_a,
                 route=route_ba, network=network)
    return SimEndpoint(ab, ba), SimEndpoint(ba, ab)
