"""Transport abstraction under the server/client protocol.

The paper uses two-way SyncManager queues; we keep that for the local
engine (``MPTransport``) and add a deterministic in-memory transport for
the simulator (``SimTransport``, driven by a virtual clock with optional
latency and scripted link failures).  Server/client code only ever sees
``Channel`` objects, so the *same* protocol code runs under both.
"""
from __future__ import annotations

import collections
import queue as _queue


class Channel:
    """One direction of a two-way link."""

    def send(self, msg) -> None:
        raise NotImplementedError

    def poll(self):
        """Non-blocking receive; returns a Message or None."""
        raise NotImplementedError

    def drain(self, limit: int = 1000) -> list:
        out = []
        for _ in range(limit):
            m = self.poll()
            if m is None:
                break
            out.append(m)
        return out


class Endpoint(Channel):
    """A two-way channel end (send one way, poll the other)."""


# ---------------------------------------------------------------------------
# multiprocessing transport (LocalEngine)
# ---------------------------------------------------------------------------
class MPChannel(Channel):
    def __init__(self, send_q, recv_q):
        self._send = send_q
        self._recv = recv_q

    def send(self, msg):
        self._send.put(msg)

    def poll(self):
        try:
            return self._recv.get_nowait()
        except (_queue.Empty, OSError, EOFError):
            return None


def mp_pipe(manager):
    """Two-way channel pair over a multiprocessing.Manager."""
    q1, q2 = manager.Queue(), manager.Queue()
    return MPChannel(q1, q2), MPChannel(q2, q1)


# ---------------------------------------------------------------------------
# simulated transport (SimEngine)
# ---------------------------------------------------------------------------
class SimWire:
    """One-directional wire with latency on a virtual clock.

    ``on_deliver`` (optional) is called with the delivery timestamp of every
    accepted message — the discrete-event engine uses it to wake the
    receiving node exactly when the message becomes readable, instead of
    polling every ``dt``.  ``jitter`` adds U[0, jitter) seconds per message
    from a seeded ``rng`` (delivery order within a wire stays FIFO: a
    message is never readable before its predecessors)."""

    def __init__(self, clock, latency: float = 0.0, jitter: float = 0.0,
                 rng=None, on_deliver=None):
        self._clock = clock
        self.latency = latency
        self.jitter = jitter
        self._rng = rng
        self._q = collections.deque()   # (deliver_at, msg)
        self.broken = False             # scripted link failure
        self.on_deliver = on_deliver

    def put(self, msg):
        if self.broken:
            return  # dropped, like a dead instance's socket
        delay = self.latency
        if self.jitter > 0.0 and self._rng is not None:
            delay += self._rng.uniform(0.0, self.jitter)
        deliver_at = self._clock.now() + delay
        if self._q and self._q[-1][0] > deliver_at:
            deliver_at = self._q[-1][0]   # FIFO: never overtake
        self._q.append((deliver_at, msg))
        if self.on_deliver is not None:
            self.on_deliver(deliver_at)

    def get(self):
        if self._q and self._q[0][0] <= self._clock.now():
            return self._q.popleft()[1]
        return None

    def next_delivery(self) -> float | None:
        """Delivery time of the oldest queued message (None when empty) —
        lets the engine's ready-set tracking skip drained wires."""
        return self._q[0][0] if self._q else None


class SimEndpoint(Endpoint):
    def __init__(self, send_wire: SimWire, recv_wire: SimWire):
        self._send = send_wire
        self._recv = recv_wire

    @property
    def recv_wire(self) -> SimWire:
        return self._recv

    def send(self, msg):
        self._send.put(msg)

    def poll(self):
        return self._recv.get()

    def brk(self):
        self._send.broken = True
        self._recv.broken = True


def sim_link(clock, latency: float = 0.0, jitter: float = 0.0, rng=None,
             notify_a=None, notify_b=None):
    """Returns (endpoint_a, endpoint_b) — a two-way simulated link.

    ``notify_a``/``notify_b`` are delivery callbacks for messages *received*
    by endpoint a / endpoint b respectively (wire direction b->a feeds a)."""
    ab = SimWire(clock, latency, jitter, rng, on_deliver=notify_b)
    ba = SimWire(clock, latency, jitter, rng, on_deliver=notify_a)
    return SimEndpoint(ab, ba), SimEndpoint(ba, ab)
