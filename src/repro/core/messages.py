"""Protocol messages (names follow the paper exactly)."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field


class MsgType(enum.Enum):
    # client/backup -> primary
    HEALTH_UPDATE = "HEALTH_UPDATE"
    REQUEST_TASKS = "REQUEST_TASKS"
    RESULT = "RESULT"
    REPORT_HARD_TASK = "REPORT_HARD_TASK"
    LOG = "LOG"
    EXCEPTION = "EXCEPTION"
    BYE = "BYE"
    # primary -> client
    GRANT_TASKS = "GRANT_TASKS"
    NO_FURTHER_TASKS = "NO_FURTHER_TASKS"
    APPLY_DOMINO_EFFECT = "APPLY_DOMINO_EFFECT"
    STOP = "STOP"
    RESUME = "RESUME"
    SWAP_QUEUES = "SWAP_QUEUES"
    # primary <-> backup coordination
    NEW_CLIENT = "NEW_CLIENT"
    CLIENT_TERMINATED = "CLIENT_TERMINATED"
    FORWARD = "FORWARD"           # copy of a client message, primary->backup
    # instance -> server bootstrap
    HANDSHAKE = "HANDSHAKE"


_seq = itertools.count()


@dataclass
class Message:
    type: MsgType
    sender: str
    body: object = None
    seq: int = field(default_factory=lambda: next(_seq))
    # server->client messages carry a per-client logical counter so clients
    # can dedup the primary's message against the backup's mirror of it
    srv_seq: int | None = None

    def key(self):
        """Dedup key for the two-copy delivery protocol (client->server
        copies share the same Message.seq)."""
        return (self.sender, self.seq)
