"""Protocol messages (names follow the paper exactly)."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any


class MsgType(enum.Enum):
    # client/backup -> primary
    HEALTH_UPDATE = "HEALTH_UPDATE"
    REQUEST_TASKS = "REQUEST_TASKS"
    RESULT = "RESULT"
    REPORT_HARD_TASK = "REPORT_HARD_TASK"
    LOG = "LOG"
    EXCEPTION = "EXCEPTION"
    BYE = "BYE"
    # primary -> client
    ACK = "ACK"                   # ack of a state-bearing client message
    GRANT_TASKS = "GRANT_TASKS"
    NO_FURTHER_TASKS = "NO_FURTHER_TASKS"
    APPLY_DOMINO_EFFECT = "APPLY_DOMINO_EFFECT"
    STOP = "STOP"
    RESUME = "RESUME"
    SWAP_QUEUES = "SWAP_QUEUES"
    # primary <-> backup coordination
    NEW_CLIENT = "NEW_CLIENT"
    CLIENT_TERMINATED = "CLIENT_TERMINATED"
    FORWARD = "FORWARD"           # copy of a client message, primary->backup
    BROADCAST = "BROADCAST"       # control broadcast notice, primary->backup
    RESYNC_REQUEST = "RESYNC_REQUEST"   # backup detected a replication gap
    SYNC_STATE = "SYNC_STATE"     # fresh snapshot, primary->backup (resync)
    # instance -> server bootstrap
    HANDSHAKE = "HANDSHAKE"


_seq = itertools.count()


@dataclass(slots=True)
class Message:
    type: MsgType
    sender: str
    # payload shape varies per MsgType (dict for task grants, tuple for
    # results, bytes for snapshots) — handlers narrow it at the use site
    body: Any = None
    seq: int = field(default_factory=lambda: next(_seq))
    # server->client messages carry a per-client logical counter so clients
    # can dedup the primary's message against the backup's mirror of it
    srv_seq: int | None = None
    # control broadcasts (STOP/RESUME) instead carry a control-plane
    # counter shared by all clients: one logical broadcast, one number —
    # per-client srv_seq is never consumed, so the backup's mirrored
    # srv_seq state cannot diverge from the primary's across broadcasts
    ctrl_seq: int | None = None

    def key(self):
        """Dedup key for the two-copy delivery protocol (client->server
        copies share the same Message.seq)."""
        return (self.sender, self.seq)
