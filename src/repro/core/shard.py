"""Sharded hierarchical scheduling (fleet scale).

A single ``SchedulerCore`` handles every client message and policy tick
of a run; past ~10k clients / 100k tasks the one scheduler process is
the bottleneck even with indexed hot paths.  This module is the pure
meta-scheduling layer that splits one experiment across K independent
primary(+backup) scheduler pairs:

  * :func:`partition_tasks` slices the hardness-sorted task table into K
    contiguous-hardness shards (each shard's ``MinHardSet`` then covers a
    compact region of the partial order, so frontiers stay small);
  * :class:`ShardCoordinator` tracks which frontier elements each shard
    has published and queues them for delivery to every *other* shard —
    cross-shard gossip makes the domino rule global: a hardness that
    timed out in shard j prunes dominated tasks everywhere, exactly as a
    single scheduler would have pruned them.  Delivery is queued per
    shard, so a shard whose primary is mid-takeover receives the gossip
    on the next pump instead of losing it;
  * :func:`merge_results` / :func:`merge_cost_summaries` reassemble the
    per-shard results tables and cost accounts into the one table a
    single-scheduler run would have produced (rows back in submission
    order, costs summed per kind).

Everything here is a pure state machine: hardness values in, hardness
values out.  Transport, clocks and engines live in the shells
(``repro.core.sim.ShardedSimCluster`` drives K ``Server`` shells on one
event loop); this module must stay deterministic and snapshot-complete —
``ShardCoordinator.snapshot()``/``restore()`` round-trip the gossip
state so a sharded run can resume without re-gossiping or, worse,
re-delivering a pruning frontier only to some shards.
"""
from __future__ import annotations

from repro.core.results import ResultsTable


def partition_tasks(tasks, n_shards: int) -> list[list[int]]:
    """Split ``tasks`` into ``n_shards`` contiguous slices of the
    hardness-sorted order, returning per-shard lists of *original*
    indices (the shard's task list is ``[tasks[i] for i in indices]``,
    in the returned order).  Uses the same sort key as ``SchedulerCore``
    (componentwise hardness values, stable), so shard k's tasks are
    never harder than shard k+1's under the total order the scheduler
    assigns in."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    order = sorted(range(len(tasks)),
                   key=lambda i: tuple(tasks[i].hardness().values))
    base, extra = divmod(len(order), n_shards)
    out: list[list[int]] = []
    pos = 0
    for k in range(n_shards):
        size = base + (1 if k < extra else 0)
        out.append(order[pos:pos + size])
        pos += size
    return out


class ShardCoordinator:
    """Cross-shard ``MinHardSet`` gossip state (the meta-scheduler).

    ``observe(k, frontier)`` diffs shard k's current frontier snapshot
    against everything gossiped so far and enqueues each fresh hardness
    for every other shard; ``take_pending(k)`` drains shard k's queue
    for delivery (``Server.apply_gossip``).  The seen-set is global —
    a hardness is gossiped at most once no matter how many shards
    independently discover it — while delivery is queued per shard, so
    a shard with no acting primary at pump time (takeover in flight)
    still receives the frontier later instead of silently missing it.
    """

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.seen: set = set()      # hardness value tuples gossiped so far
        # per-shard delivery queues of hardness value tuples
        self.pending: list[list] = [[] for _ in range(n_shards)]

    def observe(self, shard_id: int, frontier_values) -> list:
        """Record shard ``shard_id``'s frontier (an iterable of hardness
        value tuples, e.g. ``MinHardSet.snapshot()``); returns the fresh
        ones and queues them for every other shard."""
        fresh: list = []
        for hv in frontier_values:
            hv = tuple(hv)
            if hv in self.seen:
                continue
            self.seen.add(hv)
            fresh.append(hv)
            for j in range(self.n_shards):
                if j != shard_id:
                    self.pending[j].append(hv)
        return fresh

    def take_pending(self, shard_id: int) -> list:
        """Drain shard ``shard_id``'s queued gossip deliveries."""
        out, self.pending[shard_id] = self.pending[shard_id], []
        return out

    def snapshot(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "seen": sorted(self.seen),
            "pending": [list(q) for q in self.pending],
        }

    @classmethod
    def restore(cls, snap: dict) -> ShardCoordinator:
        coord = cls.__new__(cls)
        coord.n_shards = snap["n_shards"]
        coord.seen = {tuple(hv) for hv in snap["seen"]}
        coord.pending = [[tuple(hv) for hv in q] for q in snap["pending"]]
        return coord


def pump_gossip(coordinator: ShardCoordinator, servers: dict) -> int:
    """One gossip round: publish every acting primary's frontier, then
    deliver queued hardnesses to each.  ``servers`` maps shard id to its
    acting primary ``Server`` (shards mid-takeover are simply absent —
    their queues keep accumulating).  Returns the number of deliveries
    performed (a delivery may be a no-op when the receiving frontier
    already dominates it; ``apply_gossip`` decides)."""
    for k, srv in servers.items():
        coordinator.observe(k, srv.min_hard.snapshot())
    delivered = 0
    for k, srv in servers.items():
        pending = coordinator.take_pending(k)
        if pending:
            # one batched delivery per shard per pump: the server fans
            # out a single counterless message per client for the lot
            srv.apply_gossip(pending)
            delivered += len(pending)
    return delivered


def merge_cost_summaries(summaries) -> dict | None:
    """Aggregate per-shard ``CostMeter.summary()`` dicts into one
    run-level account (totals and instance-seconds summed, ``by_kind``
    summed per kind, instance counts added).  ``None`` entries (shards
    without cost accounting) are skipped; all-``None`` yields ``None``.
    """
    present = [s for s in summaries if s]
    if not present:
        return None
    by_kind: dict = {}
    for s in present:
        for kind, v in (s.get("by_kind") or {}).items():
            by_kind[kind] = round(by_kind.get(kind, 0.0) + v, 6)
    return {
        "total": round(sum(s.get("total", 0.0) for s in present), 6),
        "instance_seconds": round(
            sum(s.get("instance_seconds", 0.0) for s in present), 6),
        "by_kind": dict(sorted(by_kind.items())),
        "instances": sum(s.get("instances", 0) for s in present),
    }


def merge_results(tables, shard_indices) -> ResultsTable:
    """Reassemble per-shard :class:`ResultsTable`s into the single table
    a one-scheduler run would have written: rows back in original
    submission order (via the ``partition_tasks`` index lists), per-row
    costs preserved, cost summaries merged.  Raises when a shard's row
    count disagrees with its index list — group retention
    (``min_group_size``) must not run per shard (a group split across
    shards would be dropped wrongly), so sharded runs reject it
    upstream and this merge insists on complete tables."""
    if len(tables) != len(shard_indices):
        raise ValueError(f"{len(tables)} tables for "
                         f"{len(shard_indices)} shards")
    have_costs = any(t is not None and t.row_costs is not None
                     for t in tables)
    merged: list = []
    for k, (table, idxs) in enumerate(zip(tables, shard_indices)):
        if table is None:
            raise ValueError(f"shard {k} has no results table yet")
        if table.dropped_groups:
            raise ValueError(
                f"shard {k} dropped groups {table.dropped_groups!r}: "
                "min_group_size retention cannot be applied per shard")
        if len(table.rows) != len(idxs):
            raise ValueError(
                f"shard {k} returned {len(table.rows)} rows for "
                f"{len(idxs)} tasks — every task must reach exactly one "
                "terminal status")
        costs = table.row_costs if table.row_costs is not None \
            else [None] * len(idxs)
        for gi, row, cost in zip(idxs, table.rows, costs):
            merged.append((gi, row, cost))
    merged.sort(key=lambda x: x[0])
    first = next((t for t in tables if t.rows), tables[0])
    return ResultsTable(
        parameter_titles=first.parameter_titles,
        result_titles=first.result_titles,
        rows=[row for _, row, _ in merged],
        dropped_groups=[],
        row_costs=[c for _, _, c in merged] if have_costs else None,
        cost=merge_cost_summaries([t.cost for t in tables]),
    )


__all__ = [
    "partition_tasks", "ShardCoordinator", "pump_gossip",
    "merge_results", "merge_cost_summaries",
]
