"""Declarative parameter spaces — the researcher-facing front half of the
unified experiment API.

The paper's workflow is "write nested loops that build Task objects"; this
module replaces the loops with a declarative grid:

    space = ParamSpace.grid(
        alg=axis(["brute", "bnb", "bnb+h"], hardness=lambda v: RANK[v]),
        n_tasks=axis(range(2, 9), hardness="asc"),
        n_agents=axis(lambda c: range(c["n_tasks"], 9), hardness="asc"),
        id=range(3),
    )

    @task(result_titles=("optimal", "nodes"), timeout=5.0)
    def solve(alg, n_tasks, n_agents, id):
        ...
        return optimal, nodes

    tasks = space.bind(solve).tasks()      # full AbstractTask objects

Axes declare their **hardness direction** (``"asc"``: larger value ==
longer runtime, ``"desc"``: the opposite, or a callable mapping the value
to a monotone rank) so the domino-pruning partial order is derived from
the spec instead of hand-written per Task subclass.  Axes may be
**conditional** (``when=`` predicate over the earlier axes of the cell)
or **dependent** (a callable domain producing the axis values from the
earlier axes of the cell).
"""
from __future__ import annotations

import functools
import importlib
from dataclasses import dataclass

from repro.core.task import AbstractTask, filter_out

_DIRECTIONS = ("asc", "desc")


@dataclass(frozen=True)
class Axis:
    """One grid dimension.  ``values`` is an iterable, or a callable
    ``cell -> iterable`` for domains that depend on earlier axes.
    ``hardness`` declares the axis' monotone relation to runtime
    (``"asc"`` / ``"desc"`` / callable / None = not a hardness axis).
    ``when`` (predicate over the partial cell) gates the axis: inactive
    cells take ``default`` and do not multiply the grid."""

    values: object
    hardness: object = None
    when: object = None
    default: object = None

    def __post_init__(self):
        if self.hardness is not None and self.hardness not in _DIRECTIONS \
                and not callable(self.hardness):
            raise ValueError(
                f"hardness must be 'asc', 'desc' or a callable, "
                f"got {self.hardness!r}")

    def domain(self, cell: dict) -> tuple:
        vals = self.values(cell) if callable(self.values) else self.values
        return tuple(vals)

    def hardness_of(self, value, cell: dict):
        """Monotone hardness component for ``value`` (None if this axis
        does not participate in the partial order)."""
        if self.hardness is None:
            return None
        dom = self.domain(cell)
        if value not in dom:
            # conditional default outside the domain: easier than every
            # declared value, uniformly (callables only ever see declared
            # values, so a {value: rank} mapping need not handle it)
            return float("-inf")
        if callable(self.hardness):
            return self.hardness(value)
        if isinstance(value, (int, float)) and not isinstance(value, bool):
            return value if self.hardness == "asc" else -value
        if callable(self.values):
            # a per-cell domain gives the same value different ranks in
            # different cells — the partial order would be inconsistent
            raise ValueError(
                "rank-based hardness ('asc'/'desc') on a dependent "
                "(callable) domain with non-numeric values is ambiguous; "
                "pass hardness=<callable mapping value -> rank> instead")
        rank = dom.index(value)
        return rank if self.hardness == "asc" else -rank


def axis(values, hardness=None, when=None, default=None) -> Axis:
    """Declare a grid axis (see ``Axis``)."""
    return Axis(values, hardness=hardness, when=when, default=default)


def _as_axis(spec) -> Axis:
    if isinstance(spec, Axis):
        return spec
    if not isinstance(spec, (str, bytes)) \
            and (callable(spec) or hasattr(spec, "__iter__")):
        return Axis(spec)
    return Axis((spec,))        # scalar: a fixed single-value axis


class ParamSpace:
    """An ordered set of named axes; iterating yields cells (dicts)."""

    def __init__(self, axes: dict, factory: TaskFactory | None = None):
        if not axes:
            raise ValueError("ParamSpace needs at least one axis")
        self.axes: dict[str, Axis] = {n: _as_axis(a) for n, a in axes.items()}
        self.factory = factory
        self._expanded: list[dict] | None = None   # cells() cache

    @classmethod
    def grid(cls, **axes) -> ParamSpace:
        """Build a space from keyword axes; declaration order is the
        nesting order (first axis is the outermost loop) and the
        parameter-title order of the generated tasks."""
        return cls(axes)

    # ------------------------------------------------------------------
    @property
    def names(self) -> tuple:
        return tuple(self.axes)

    def _expand(self) -> list[dict]:
        """The grid, expanded once per space and cached (axes are frozen
        after construction, so the expansion never changes)."""
        if self._expanded is None:
            cells = [{}]
            for name, ax in self.axes.items():
                nxt = []
                for cell in cells:
                    if ax.when is not None and not ax.when(cell):
                        nxt.append({**cell, name: ax.default})
                        continue
                    for v in ax.domain(cell):
                        nxt.append({**cell, name: v})
                cells = nxt
            self._expanded = cells
        return self._expanded

    def cells(self) -> list[dict]:
        return [dict(c) for c in self._expand()]   # caller-owned copies

    def __iter__(self):
        return iter(self.cells())

    def __len__(self):
        return len(self._expand())

    # ------------------------------------------------------------------
    def hardness_titles(self) -> tuple:
        return tuple(n for n, ax in self.axes.items()
                     if ax.hardness is not None)

    def hardness_of(self, cell: dict) -> tuple:
        """The cell's hardness tuple — one monotone component per axis
        that declared a hardness direction, in axis order."""
        out = []
        for name, ax in self.axes.items():
            h = ax.hardness_of(cell[name], cell)
            if h is not None:
                out.append(h)
        return tuple(out)

    # ------------------------------------------------------------------
    def bind(self, factory) -> ParamSpace:
        """Attach a ``@task``-decorated function (or plain callable) the
        cells will be run through; returns a new bound space."""
        if not isinstance(factory, TaskFactory):
            factory = task(factory)
        return ParamSpace(dict(self.axes), factory=factory)

    def tasks(self, factory=None, timeout=None) -> list:
        """Materialize one ``AbstractTask`` per cell.

        ``timeout`` overrides the factory's per-cell deadline (scalar or
        ``callable(cell)``); the resolved float is baked into each task so
        tasks stay picklable regardless of where the override came from.
        """
        factory = factory or self.factory
        if factory is None:
            raise ValueError("unbound space: pass a @task function or "
                             "call .bind(fn) first")
        if not isinstance(factory, TaskFactory):
            factory = task(factory)
        out = []
        for cell in self._expand():
            hardness = factory.resolve_hardness(cell, self)
            t = factory.resolve_timeout(cell) if timeout is None \
                else (timeout(cell) if callable(timeout) else timeout)
            if t is not None and not hardness:
                raise ValueError(
                    "a timeout needs a hardness order to prune against: "
                    "declare hardness= on at least one axis (or on @task)")
            out.append(FunctionTask(
                factory=factory,
                cell=cell,
                hardness_values=hardness,
                timeout=t,
                sim_duration=factory.resolve_sim_duration(cell),
            ))
        return out


# ---------------------------------------------------------------------------
# the @task decorator
# ---------------------------------------------------------------------------
def _load_factory(module: str, qualname: str):
    obj = importlib.import_module(module)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    if not isinstance(obj, TaskFactory):
        raise TypeError(f"{module}.{qualname} is not a @task function")
    return obj


class TaskFactory:
    """A plain function elevated to a task template (see ``task``).

    Instances pickle by reference (module + qualname), exactly like
    functions do — define ``@task`` functions at module level when tasks
    must cross process boundaries (LocalEngine workers, backup
    snapshots)."""

    def __init__(self, fn, result_titles=None, timeout=None,
                 sim_duration=None, hardness=None, group_by=None):
        functools.update_wrapper(self, fn)
        self.fn = fn
        self.result_titles = tuple(result_titles) if result_titles else None
        self.timeout = timeout
        self.sim_duration = sim_duration
        self.hardness = hardness        # callable(cell) -> tuple override
        self.group_by = tuple(group_by) if group_by else None

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)

    def __reduce__(self):
        return (_load_factory, (self.__module__, self.__qualname__))

    # --- per-cell resolution (called at build time by ParamSpace) -----
    @staticmethod
    def _resolve(spec, cell):
        return spec(**cell) if callable(spec) else spec

    def resolve_timeout(self, cell):
        return self._resolve(self.timeout, cell)

    def resolve_sim_duration(self, cell):
        return self._resolve(self.sim_duration, cell)

    def resolve_hardness(self, cell, space: ParamSpace) -> tuple:
        if self.hardness is not None:
            return tuple(self.hardness(**cell))
        return space.hardness_of(cell)


def task(fn=None, *, result_titles=None, timeout=None, sim_duration=None,
         hardness=None, group_by=None):
    """Decorator: turn a plain function into a task template.

    The function's keyword arguments are the space's axis names; its
    return value is the result tuple (a scalar is wrapped).  Options:

    * ``result_titles`` — column names of the returned tuple,
    * ``timeout``       — per-cell deadline, scalar or ``fn(**cell)``,
    * ``sim_duration``  — virtual runtime for the simulator, scalar or
      ``fn(**cell)`` (required to run this task under ``engine="sim"``),
    * ``hardness``      — ``fn(**cell) -> tuple`` overriding the
      axis-derived hardness,
    * ``group_by``      — parameter titles forming the retention group
      (default: every title except ``id``).
    """
    def wrap(f):
        return TaskFactory(f, result_titles=result_titles, timeout=timeout,
                           sim_duration=sim_duration, hardness=hardness,
                           group_by=group_by)
    return wrap if fn is None else wrap(fn)


class FunctionTask(AbstractTask):
    """AbstractTask over a ``@task`` function and one space cell.  All
    per-cell quantities (hardness, timeout, sim duration) are resolved at
    build time, so instances are plain picklable data + a by-reference
    function."""

    def __init__(self, factory: TaskFactory, cell: dict,
                 hardness_values: tuple, timeout: float | None = None,
                 sim_duration: float | None = None):
        self._factory = factory
        self._cell = dict(cell)
        self._hard = tuple(hardness_values)
        self._timeout = timeout
        if sim_duration is not None:
            # attribute protocol of SimWorkerPool
            self.sim_duration = float(sim_duration)

    # --- identity / reporting -----------------------------------------
    def parameter_titles(self):
        return tuple(self._cell)

    def parameters(self):
        return tuple(self._cell.values())

    def result_titles(self):
        return self._factory.result_titles or ("value",)

    def hardness_parameters(self):
        return self._hard

    # --- execution -----------------------------------------------------
    def run(self):
        out = self._factory.fn(**self._cell)
        if isinstance(out, (tuple, list)):
            return tuple(out)
        return (out,)

    def timeout(self):
        return self._timeout

    def group_parameter_titles(self):
        if self._factory.group_by is not None:
            return self._factory.group_by
        return filter_out(self.parameter_titles(), ("id",))


__all__ = ["Axis", "axis", "ParamSpace", "task", "TaskFactory",
           "FunctionTask"]
