"""The one-call experiment facade + streaming run handle.

Every scenario — simulated cloud, local processes, GCE, TPU pods — enters
through the same front door:

    exp = Experiment(space.bind(solve), engine="sim", scale="demand",
                     budget_cap=400.0, backup=True,
                     chaos=[SpotWave(at=8.0, fraction=0.5)])
    with exp.run() as run:
        for ev in run.events():
            ...                      # typed RunEvents as they happen
        table = run.results()        # ResultsTable incl. cost summary

``Experiment`` resolves engines through the :mod:`repro.core.engines`
registry, so ``SimCluster`` scenarios (spot waves, partitions, traces)
and real engines are configured identically; ``run()`` returns a
:class:`RunHandle` that streams typed events, exposes ``results()``,
scopes shutdown to a ``with`` block and supports ``snapshot()`` /
``Experiment.resume()`` from the scheduler core's structured snapshot.
"""
from __future__ import annotations

import dataclasses
import pickle
import time as _time
from dataclasses import dataclass

from repro.core import engines as _engines
from repro.core.engine import AbstractEngine
from repro.core.policy import CostMeter
from repro.core.scheduler import DONE, PENDING, PRUNED, TIMED_OUT
from repro.core.server import Server, ServerConfig
from repro.core.sim import ShardedSimCluster, SimCluster
from repro.core.space import ParamSpace, TaskFactory
from repro.core.task import AbstractTask


# ---------------------------------------------------------------------------
# typed run events (streamed by RunHandle.events())
# ---------------------------------------------------------------------------
@dataclass
class RunEvent:
    """Base class; ``t`` is engine time (virtual seconds on the
    simulator, wall-clock seconds on real engines)."""

    t: float


@dataclass
class TaskSolved(RunEvent):
    params: tuple
    result: tuple


@dataclass
class TaskPruned(RunEvent):
    params: tuple


@dataclass
class TaskTimedOut(RunEvent):
    params: tuple


@dataclass
class InstanceCreated(RunEvent):
    name: str
    kind: str


@dataclass
class InstanceTerminated(RunEvent):
    name: str
    kind: str


@dataclass
class InstancePreempted(RunEvent):
    name: str


@dataclass
class CostTick(RunEvent):
    total: float
    by_kind: dict


@dataclass
class RunDone(RunEvent):
    solved: int
    pruned: int
    timed_out: int
    cost: float | None = None


# ---------------------------------------------------------------------------
# chaos directives (simulator only; see SimCluster for the mechanisms)
# ---------------------------------------------------------------------------
@dataclass
class SpotWave:
    """Kill ``fraction`` of the alive preemptible clients at time ``at``."""

    at: float
    fraction: float


@dataclass
class Partition:
    """Drop messages on the a<->b link (roles or client names), optionally
    scheduled (``at``) and auto-healing (``until``)."""

    a: str
    b: str
    direction: str = "both"
    at: float | None = None
    until: float | None = None


@dataclass
class KillPrimary:
    """Crash the primary server at time ``at`` (backup takeover drill)."""

    at: float


def _apply_chaos(cluster: SimCluster, directives) -> None:
    for c in directives:
        if isinstance(c, SpotWave):
            cluster.spot_wave(c.at, c.fraction)
        elif isinstance(c, Partition):
            cluster.partition(c.a, c.b, c.direction, at=c.at, until=c.until)
        elif isinstance(c, KillPrimary):
            cluster.at(c.at, lambda cl: cl.kill_primary())
        elif callable(c):
            c(cluster)           # escape hatch: arbitrary scripting
        else:
            raise TypeError(f"unknown chaos directive: {c!r}")


# ---------------------------------------------------------------------------
# state watcher: diffs observable scheduler/engine state into RunEvents
# ---------------------------------------------------------------------------
class _RunWatcher:
    def __init__(self, cost_tick_s: float):
        self.cost_tick_s = cost_tick_s
        self._prev_status: list | None = None
        self._created: set[str] = set()
        self._terminated: set[str] = set()
        self._alive_prev: dict[str, bool] = {}
        self._last_cost_tick: float | None = None
        self._meter = CostMeter()

    def poll(self, server: Server, engine, now: float) -> list[RunEvent]:
        evs: list[RunEvent] = []
        core = server.core
        st = core.status
        if self._prev_status is None or len(self._prev_status) != len(st):
            self._prev_status = [PENDING] * len(st)
        for tid, s in enumerate(st):
            if s == self._prev_status[tid]:
                continue
            self._prev_status[tid] = s
            params = core.tasks[tid].parameters()
            if s == DONE:
                evs.append(TaskSolved(now, params, core.results.get(tid)))
            elif s == TIMED_OUT:
                evs.append(TaskTimedOut(now, params))
            elif s == PRUNED:
                evs.append(TaskPruned(now, params))
        alive = getattr(engine, "alive", None)
        alive_changed = False
        if isinstance(alive, dict):
            for name, up in alive.items():
                if self._alive_prev.get(name, up) and not up:
                    evs.append(InstancePreempted(now, name))
            alive_changed = alive != self._alive_prev
            if alive_changed:
                self._alive_prev = dict(alive)
        tick_due = self._last_cost_tick is not None \
            and now - self._last_cost_tick >= self.cost_tick_s
        # materializing billing_records() every poll is the hot cost of
        # the streaming path: engines with a liveness dict (the sim) are
        # only polled when something observable changed or a tick is due
        if alive is None or alive_changed or evs or tick_due \
                or self._last_cost_tick is None:
            records = engine.billing_records() or []
            for rec in records:
                name, kind, _rate, _start, end = rec[:5]
                if name not in self._created:
                    self._created.add(name)
                    evs.append(InstanceCreated(now, name, kind))
                if end is not None and name not in self._terminated:
                    self._terminated.add(name)
                    evs.append(InstanceTerminated(now, name, kind))
            if self._last_cost_tick is None:
                self._last_cost_tick = now
            elif tick_due:
                self._last_cost_tick = now
                self._meter.sync(records)
                evs.append(CostTick(now, self._meter.accrued(now),
                                    self._meter.by_kind(now)))
        return evs


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------
def _server_config_fields():
    return {f.name for f in dataclasses.fields(ServerConfig)}


class Experiment:
    """One front door over sim/local/GCE/TPU runs.

    ``space_or_tasks`` — a (bound) :class:`ParamSpace`, or an iterable of
    ``AbstractTask`` objects.  ``task`` binds an unbound space.

    ``engine`` — a registry name (``"sim"``/``"local"``/``"gce"``/
    ``"tpu"`` or anything ``engines.register``-ed), or a ready
    ``AbstractEngine`` instance; ``engine_cfg`` is the registry factory's
    keyword config and ``sim=`` is sugar for ``engine_cfg`` field values
    of ``SimParams`` when ``engine="sim"``.

    ``scale`` / ``budget_cap`` / ``backup`` / ``max_clients`` /
    ``out_dir`` and any extra ``ServerConfig`` field passed as a keyword
    build the server config (or pass a full ``config=ServerConfig``).

    ``chaos`` — simulator-only fault script: :class:`SpotWave`,
    :class:`Partition`, :class:`KillPrimary`, or ``callable(cluster)``.

    ``shards`` — split the run across K independent primary(+backup)
    scheduler pairs (simulator only): the hardness-sorted task table is
    partitioned into K contiguous slices, each shard runs its own fleet
    under the per-shard ``ServerConfig`` (``max_clients`` etc. apply
    *per shard*), and timed-out hardness frontiers gossip across shards
    so domino pruning stays global.  ``results()`` returns the merged
    table in submission order, exactly as ``shards=1`` would.
    """

    def __init__(self, space_or_tasks, *, task=None, engine: object = "sim",
                 engine_cfg: dict | None = None, sim: object = None,
                 scale: str = "fixed", budget_cap: float | None = None,
                 backup: bool = False, max_clients: int = 4,
                 out_dir: str | None = None, chaos=(), shards: int = 1,
                 config: ServerConfig | None = None, **server_cfg):
        self.tasks = self._resolve_tasks(space_or_tasks, task)
        self.engine = engine
        self.shards = int(shards)
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.engine_cfg = dict(engine_cfg or {})
        if self.engine_cfg and not isinstance(engine, str):
            raise ValueError(
                "engine_cfg is only consumed by registry names; this "
                "engine is already constructed — configure it directly")
        if sim is not None:
            if engine != "sim":
                raise ValueError("sim= is only meaningful with engine='sim'")
            if isinstance(sim, dict):
                self.engine_cfg.update(sim)
            else:
                self.engine_cfg["params"] = sim
        self.chaos = tuple(chaos)
        # fail fast for the built-in real engines; custom registered
        # names are validated against the resolved spec at start time
        if self.chaos and (isinstance(engine, str)
                           and engine in ("local", "gce", "tpu")
                           or isinstance(engine, AbstractEngine)):
            raise ValueError("chaos directives require a simulator engine")
        if self.shards > 1:
            # sharding is a simulator feature: K Server shells on one
            # event loop.  Real engines run one scheduler per process.
            if isinstance(engine, AbstractEngine) or (
                    isinstance(engine, str)
                    and engine in ("local", "gce", "tpu")):
                raise ValueError(
                    "shards>1 requires the simulator engine "
                    "(engine='sim')")
            if self.chaos:
                raise ValueError(
                    "chaos directives are not supported with shards>1 "
                    "yet — script faults via the cluster directly")
        if config is not None:
            overridden = [k for k, v, d in (
                ("scale", scale, "fixed"), ("budget_cap", budget_cap, None),
                ("backup", backup, False), ("max_clients", max_clients, 4),
                ("out_dir", out_dir, None)) if v != d]
            if server_cfg or overridden:
                raise ValueError(
                    f"pass either config=ServerConfig(...) or field "
                    f"overrides, not both: "
                    f"{sorted(server_cfg) + sorted(overridden)}")
            self.config = config
        else:
            unknown = set(server_cfg) - _server_config_fields()
            if unknown:
                raise ValueError(
                    f"unknown ServerConfig fields: {sorted(unknown)}")
            self.config = ServerConfig(
                max_clients=max_clients, use_backup=backup,
                scale_policy=scale, budget_cap=budget_cap,
                out_dir=out_dir, **server_cfg)
        if self.shards > 1 and self.config.min_group_size > 0:
            raise ValueError(
                "min_group_size retention cannot run per shard (a group "
                "split across shards would be dropped wrongly) — use "
                "shards=1 or min_group_size=0")

    @staticmethod
    def _resolve_tasks(space_or_tasks, task) -> list:
        if isinstance(space_or_tasks, ParamSpace):
            space = space_or_tasks
            if task is not None:
                space = space.bind(task)
            return space.tasks()
        if isinstance(space_or_tasks, (TaskFactory,)) or \
                isinstance(task, ParamSpace):
            raise TypeError("pass the ParamSpace first and the @task "
                            "function as task= (or bind the space)")
        tasks = list(space_or_tasks)
        for t in tasks:
            if not isinstance(t, AbstractTask):
                raise TypeError(f"not an AbstractTask: {t!r}")
        return tasks

    # ------------------------------------------------------------------
    def run(self) -> RunHandle:
        """Start (lazily) and return the streaming run handle."""
        return RunHandle(self)

    def resume(self, snapshot: bytes) -> RunHandle:
        """Resume from a ``RunHandle.snapshot()`` blob: solved results are
        kept, in-flight assignments are requeued (at-least-once), and the
        run continues on a fresh fleet."""
        return RunHandle(self, resume_blob=snapshot)


class RunHandle:
    """Handle over a started experiment: stream events, fetch results,
    snapshot, and ``with``-scope the engine shutdown."""

    def __init__(self, exp: Experiment, resume_blob: bytes | None = None):
        self._exp = exp
        self._resume_blob = resume_blob
        self._cluster = None       # SimCluster | ShardedSimCluster
        self._server: Server | None = None
        self._engine = None
        self._table = None
        self._started = False
        self._closed = False
        self._sharded = False

    # ------------------------------------------------------------------
    # lazy start
    # ------------------------------------------------------------------
    def _start(self):
        if self._started:
            return
        self._started = True
        exp = self._exp
        spec = _engines.make(exp.engine, **exp.engine_cfg) \
            if isinstance(exp.engine, str) else exp.engine
        # a sharded snapshot carries its own shard count; resuming one
        # always takes the sharded path, whatever shards= says now
        resume_state = None
        if self._resume_blob is not None:
            resume_state = pickle.loads(self._resume_blob)
        sharded_blob = isinstance(resume_state, dict) \
            and "shards" in resume_state
        sharded = exp.shards > 1 or sharded_blob
        try:
            if exp.chaos and not isinstance(spec, _engines.SimSpec):
                raise ValueError(
                    "chaos directives require a simulator engine")
            if sharded and not isinstance(spec, _engines.SimSpec):
                raise ValueError(
                    "shards>1 requires the simulator engine (engine='sim')")
            if resume_state is not None and exp.shards > 1 \
                    and not sharded_blob:
                raise ValueError(
                    "resume blob is a single-scheduler snapshot — resume "
                    "with shards=1 (sharding cannot be added on resume)")
            if sharded:
                self._sharded = True
                self._cluster = ShardedSimCluster(
                    exp.tasks, exp.config, spec.params,
                    n_shards=exp.shards, _internal=True,
                    _resume=resume_state if sharded_blob else None)
                self._engine = self._cluster.engines[0]
            elif isinstance(spec, _engines.SimSpec):
                self._cluster = SimCluster(exp.tasks, exp.config,
                                           spec.params, _internal=True)
                self._engine = self._cluster.engine
                if self._resume_blob is not None:
                    srv = Server.resume_primary(self._resume_blob,
                                                self._engine)
                    self._cluster.server = srv
                    self._engine.backup_links = srv.config.use_backup
                _apply_chaos(self._cluster, exp.chaos)
            elif isinstance(spec, AbstractEngine):
                self._engine = spec
                self._server = (
                    Server.resume_primary(self._resume_blob, spec)
                    if self._resume_blob is not None
                    else Server(exp.tasks, spec, exp.config, _internal=True))
            else:
                raise TypeError(f"engine factory returned {spec!r}; "
                                f"expected an AbstractEngine or "
                                f"engines.SimSpec")
        except BaseException:
            # a constructed real engine must not leak (mp.Manager
            # processes, cloud state) when validation/wiring fails
            if isinstance(spec, AbstractEngine):
                spec.shutdown()
            raise

    # ------------------------------------------------------------------
    @property
    def cluster(self):
        """The underlying ``SimCluster``/``ShardedSimCluster`` (sim runs
        only) — the advanced scripting surface (``at``/``partition``/
        ``trace`` ...)."""
        self._start()
        if self._cluster is None:
            raise AttributeError("no cluster: this run uses a real engine")
        return self._cluster

    @property
    def engine(self):
        self._start()
        return self._engine

    @property
    def server(self) -> Server:
        """The acting primary server (single-scheduler runs)."""
        self._start()
        if self._sharded:
            raise AttributeError(
                "sharded run: there is no single primary — use "
                ".shard_servers")
        if self._cluster is not None:
            return self._cluster.acting_primary() or self._cluster.server
        return self._server

    @property
    def shard_servers(self) -> list[Server]:
        """The acting primary of every shard, in shard order (sharded
        runs; a single-scheduler run returns a one-element list)."""
        self._start()
        if not self._sharded:
            return [self.server]
        acting = self._cluster.acting_primaries()
        return [acting[k] for k in sorted(acting)]

    @property
    def table(self):
        """The final ResultsTable (None until the run completes)."""
        return self._table

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def events(self, until: float = 1e9, max_steps: int = 200_000,
               poll_sleep: float = 0.02, cost_tick_s: float = 5.0):
        """Generator: drive the run, yielding typed :class:`RunEvent`s as
        scheduler/engine state changes, ending with :class:`RunDone`.
        ``max_steps`` bounds simulator runs, ``until`` bounds both
        (virtual seconds on sim, wall clock on real engines);
        ``poll_sleep`` paces real-engine polling; ``cost_tick_s`` is the
        CostTick cadence (in engine time).  On a real engine the stream
        owns the fleet: abandoning it before ``RunDone`` shuts the
        engine down (``snapshot()`` + ``Experiment.resume()`` continues
        the exploration on a fresh fleet; a later ``results()`` raises
        instead of hanging)."""
        self._start()
        if self._sharded:
            yield from self._sharded_sim_events(until, max_steps,
                                                cost_tick_s)
            return
        watcher = _RunWatcher(cost_tick_s)
        if self._cluster is not None:
            yield from self._sim_events(watcher, until, max_steps)
        else:
            yield from self._real_events(watcher, until, poll_sleep)

    def _sim_events(self, watcher, until, max_steps):
        cl = self._cluster
        prim = None
        for prim in cl.steps(until=until, max_steps=max_steps):
            yield from watcher.poll(self.server, self._engine,
                                    cl.clock.now())
            if prim is not None:
                break
        self._table = prim.final_results
        yield self._done_event(cl.clock.now())

    def _sharded_sim_events(self, until, max_steps, cost_tick_s):
        # one watcher per shard: each diffs its own scheduler core and
        # engine registry, so the merged stream interleaves shard events
        # in step order
        cl = self._cluster
        watchers = [_RunWatcher(cost_tick_s) for _ in range(cl.n_shards)]
        done = None
        for done in cl.steps(until=until, max_steps=max_steps):
            now = cl.clock.now()
            acting = cl.acting_primaries()
            for k, w in enumerate(watchers):
                srv = acting.get(k)
                if srv is not None:
                    yield from w.poll(srv, cl.engines[k], now)
            if done is not None:
                break
        self._table = cl.merged_results()
        yield self._done_event(cl.clock.now())

    def _real_events(self, watcher, until, poll_sleep):
        # the single real-engine drive loop (results() drains it too).
        # The generator owns the engine's lifetime on this path: both
        # normal exhaustion and an abandoned/failed iteration must reap
        # the client process groups (shutdown is idempotent with the
        # with-block path)
        if self._closed:
            raise RuntimeError(
                "engine already shut down (a previous event stream was "
                "abandoned before RunDone) — snapshot() before abandoning "
                "and Experiment.resume() to continue")
        try:
            srv = self._server
            t0 = _time.time()
            while not srv.done:
                if _time.time() - t0 >= until:
                    raise TimeoutError(f"run did not finish within {until}s")
                srv.step()
                yield from watcher.poll(srv, self._engine, srv.now())
                _time.sleep(poll_sleep)
            self._table = srv.final_results
            yield from watcher.poll(srv, self._engine, srv.now())
            yield self._done_event(srv.now())
        finally:
            self.shutdown()

    def _done_event(self, now: float) -> RunDone:
        rows = self._table.rows
        return RunDone(
            now,
            solved=sum(1 for _, r, _ in rows if r is not None),
            pruned=sum(1 for _, _, s in rows if s == PRUNED),
            timed_out=sum(1 for _, _, s in rows if s == TIMED_OUT),
            cost=(self._table.cost or {}).get("total"),
        )

    def results(self, until: float = 1e9, max_steps: int = 200_000,
                poll_sleep: float = 0.02):
        """Drive the run to completion (no per-step event diffing — the
        fast path) and return the final ``ResultsTable``.  ``until`` is
        virtual seconds on the simulator and a wall-clock bound on real
        engines (TimeoutError past it); ``max_steps`` bounds simulator
        steps only.  Real engines are shut down once results are in
        (instances already said BYE); simulator state stays inspectable
        via ``.cluster``."""
        if self._table is not None:
            return self._table
        self._start()
        if self._sharded:
            self._cluster.run(until=until, max_steps=max_steps)
            self._table = self._cluster.merged_results()
        elif self._cluster is not None:
            prim = self._cluster.run(until=until, max_steps=max_steps)
            self._table = prim.final_results
        else:
            # drain the one real drive loop, discarding the events (a
            # never-firing cost tick keeps the watcher diff-only)
            watcher = _RunWatcher(cost_tick_s=float("inf"))
            for _ in self._real_events(watcher, until, poll_sleep):
                pass
        return self._table

    # ------------------------------------------------------------------
    # snapshot / lifecycle
    # ------------------------------------------------------------------
    def snapshot(self) -> bytes:
        """Structured snapshot of the run's scheduler state — feed to
        ``Experiment.resume()`` to continue an interrupted run.  Sharded
        runs bundle every shard's core plus the gossip coordinator."""
        self._start()
        if self._sharded:
            return self._cluster.serialize_state()
        return self.server.serialize_state()

    def shutdown(self):
        if self._closed or self._engine is None:
            return
        self._closed = True
        if self._sharded:
            for eng in self._cluster.engines:
                eng.shutdown()
        else:
            self._engine.shutdown()

    def __enter__(self) -> RunHandle:
        self._start()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.shutdown()
        return False


__all__ = [
    "Experiment", "RunHandle",
    "RunEvent", "TaskSolved", "TaskPruned", "TaskTimedOut",
    "InstanceCreated", "InstanceTerminated", "InstancePreempted",
    "CostTick", "RunDone",
    "SpotWave", "Partition", "KillPrimary",
]
