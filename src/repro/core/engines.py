"""Engine registry — one name -> engine factory table, so simulation and
real cloud backends are configured identically (paper: "provide an
extension class with methods to create, terminate and list compute
instances"; here the extension class also registers itself by name).

    engines.make("sim", client_workers=4, seed=1)      -> SimSpec
    engines.make("local", n_workers_per_client=2)      -> LocalEngine
    engines.make("gce", project=..., zone=..., ...)    -> GCEEngine
    engines.make("tpu", accelerator_type=..., ...)     -> TPUPodEngine

``"sim"`` returns a :class:`SimSpec` (the simulator needs a shared
virtual clock, so the Experiment facade builds the actual ``SimEngine``
inside a ``SimCluster``); every other name returns a ready
``AbstractEngine``.  Third-party backends plug in via ``register``.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.engine import GCEEngine, LocalEngine, TPUPodEngine
from repro.core.sim import SimParams


@dataclass
class SimSpec:
    """Deferred simulator construction: carries the ``SimParams`` until a
    run materializes the clock + ``SimEngine`` (see ``Experiment``)."""

    params: SimParams


def _make_sim(params: SimParams | None = None, **kwargs) -> SimSpec:
    if params is not None:
        if kwargs:
            raise ValueError(
                f"pass either params=SimParams(...) or keyword fields, "
                f"not both: {sorted(kwargs)}")
        return SimSpec(params)
    return SimSpec(SimParams(**kwargs))


def _make_local(n_workers_per_client: int | None = None) -> LocalEngine:
    return LocalEngine(n_workers_per_client=n_workers_per_client)


def _make_gce(runner=None, **config) -> GCEEngine:
    return GCEEngine(config, runner=runner)


def _make_tpu(runner=None, **config) -> TPUPodEngine:
    return TPUPodEngine(config, runner=runner)


_REGISTRY: dict[str, object] = {}


def register(name: str, factory) -> None:
    """Register (or replace) an engine factory under ``name``.  The
    factory receives ``make``'s keyword config and returns an
    ``AbstractEngine`` (or a ``SimSpec``-like deferred spec)."""
    if not callable(factory):
        raise TypeError(f"engine factory for {name!r} must be callable")
    _REGISTRY[name] = factory


def names() -> list[str]:
    return sorted(_REGISTRY)


def make(name: str, **cfg):
    """Build the engine registered under ``name`` with ``cfg``."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; known engines: {names()}") from None
    return factory(**cfg)


register("sim", _make_sim)
register("local", _make_local)
register("gce", _make_gce)
register("tpu", _make_tpu)

__all__ = ["SimSpec", "register", "make", "names"]
