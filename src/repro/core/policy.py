"""Pluggable scheduling policies + cost accounting (pure policy layer).

JobPruner (Silva et al., 2018) treats pruning/scaling decisions as policy
choices worth swapping independently of the mechanism that executes them;
this module is that seam for ExpoCloud.  Three orthogonal policy families
are consulted by ``SchedulerCore``:

  * ``AssignPolicy``  — which tasks a client's REQUEST_TASKS is granted
    (hardness-order FIFO, the paper's rule, or a batch/backfill variant
    that keeps contiguous hardness batches on one client),
  * ``ScalePolicy``   — when to create a new client instance and when to
    proactively terminate an idle one (fixed fleet = the paper's rule;
    demand scale = create only while remaining work exceeds committed
    capacity, downscale idle clients once the tail no longer fills them),
  * ``BudgetPolicy``  — a user-set cost cap: scaling stops when the
    projected spend threatens the cap (the paper's "budget-effective"
    claim made enforceable).

Policies are deterministic strategy objects that see only the core's
public helpers and the typed ``Tick`` event — never transports or
engines — so a scheduling run replays bit-identically from an event log.

``CostMeter`` is the end-to-end cost account: engines report billing
records (per-instance start/end plus a $/instance-second rate — exact in
the simulator, wall-clock proxies on LocalEngine/GCE), the server shell
syncs them into the meter, and the meter's summary lands in the results
table and the benchmark artifacts.
"""
from __future__ import annotations

from dataclasses import dataclass, field


# ---------------------------------------------------------------------------
# task assignment
# ---------------------------------------------------------------------------
class AssignPolicy:
    """Chooses which tasks satisfy a client's request for ``n`` tasks.

    Implementations pull from the core via ``core.take_failed()`` /
    ``core.take_next()`` (both honour MinHardSet pruning and mark
    disqualified tasks PRUNED as they are encountered)."""

    def select(self, core, n: int) -> list:
        raise NotImplementedError


class HardnessOrderPolicy(AssignPolicy):
    """The paper's rule: re-assign tasks from failed clients first, then
    grant in non-decreasing hardness order (FIFO over the sorted table)."""

    def select(self, core, n: int) -> list:
        out = []
        while len(out) < n:
            nxt = core.take_failed()
            if nxt is None:
                break
            out.append(nxt)
        while len(out) < n:
            nxt = core.take_next()
            if nxt is None:
                break
            out.append(nxt)
        return out


@dataclass
class BatchBackfillPolicy(AssignPolicy):
    """Hardness-order with batch alignment: a single grant never crosses a
    ``batch``-boundary of the sorted task table, so consecutive-hardness
    batches (e.g. one group's instances) tend to land on one client and a
    freed client backfills the next whole batch.  Failed-pool tasks are
    still re-assigned with priority, unbatched."""

    batch: int = 4

    def select(self, core, n: int) -> list:
        out = []
        while len(out) < n:
            nxt = core.take_failed()
            if nxt is None:
                break
            out.append(nxt)
        # queue grants stay within one batch of the sorted table; batches
        # are index ranges, so a task from a different batch is handed
        # back (take_next never mutates a grantable task, resetting the
        # pointer to its tid restores the queue exactly)
        first_batch = None
        while len(out) < n:
            nxt = core.take_next()
            if nxt is None:
                break
            tid = nxt[0]
            if first_batch is None:
                first_batch = tid // self.batch
            elif tid // self.batch != first_batch:
                core.next_ptr = tid
                break
            out.append(nxt)
        return out


# ---------------------------------------------------------------------------
# fleet scaling
# ---------------------------------------------------------------------------
@dataclass
class ScaleDecision:
    create: int = 0                     # client instances to request; may
    #   exceed 1 up to config.create_batch (fleet-scale batched boot)
    terminate: list = field(default_factory=list)   # idle client names


class ScalePolicy:
    def decide(self, core, tick) -> ScaleDecision:
        raise NotImplementedError


class FixedFleetPolicy(ScalePolicy):
    """The paper's rule: create while any task is assignable and the fleet
    (alive + booting) is below max_clients; never downscale proactively
    (clients self-terminate via NO_FURTHER_TASKS -> BYE).  With
    ``config.create_batch`` > 1 a single tick requests a whole batch —
    capped by fleet room and by the number of assignable tasks, so a
    short tail never boots instances with nothing to do."""

    def decide(self, core, tick) -> ScaleDecision:
        create = 0
        room = core.config.max_clients \
            - len(core.clients) - tick.pending_instances
        if tick.can_create and room > 0 and core.has_assignable():
            batch = min(room, max(1, getattr(core.config, "create_batch", 1)))
            create = core.count_assignable(batch) if batch > 1 else 1
        return ScaleDecision(create=create)


@dataclass
class DemandScalePolicy(ScalePolicy):
    """Demand-aware scaling: create a client only while the number of
    grantable tasks exceeds the committed worker capacity (alive clients'
    observed capacity + booting instances x ``workers_hint``), and
    terminate clients that hold no assigned task once nothing is
    grantable and they have been workless for ``idle_timeout_s``.

    The idle cutoff only ever selects clients with an empty assignment
    table, so downscaling can never strand an ASSIGNED task."""

    workers_hint: int = 1
    idle_timeout_s: float = 5.0

    def decide(self, core, tick) -> ScaleDecision:
        hint = max(1, self.workers_hint)
        committed = sum(max(ci.capacity, hint)
                        for ci in core.clients.values())
        # only client-kind instances contribute worker capacity — a
        # booting backup server must not suppress client creation
        committed += tick.pending_clients * hint
        room = core.config.max_clients \
            - len(core.clients) - tick.pending_clients
        create = 0
        if tick.can_create and room > 0:
            batch = min(room, max(1, getattr(core.config, "create_batch", 1)))
            # enough assignable work beyond committed capacity to fill
            # ceil(deficit / hint) more clients, up to the batch cap
            assignable = core.count_assignable(committed + hint * batch + 1)
            if assignable > committed:
                create = min(batch, -(-(assignable - committed) // hint))
        terminate = []
        if not core.has_assignable():
            for cname, ci in core.clients.items():
                if not ci.assigned \
                        and tick.now - ci.last_active > self.idle_timeout_s:
                    terminate.append(cname)
        return ScaleDecision(create=create, terminate=terminate)


# ---------------------------------------------------------------------------
# liveness (partition hardening)
# ---------------------------------------------------------------------------
@dataclass
class LivenessPolicy:
    """How long a silent client may live before it is declared dead.

    Real cloud incidents are dominated by *partial* failures — one-way
    link loss, asymmetric partitions, delayed-but-alive peers (Gent &
    Kotthoff) — so a client whose link the transport reports as
    partitioned (``ClientInfo.suspected_at`` set via the core's
    ``LinkLost`` event) gets ``partition_grace_s`` extra allowance: if
    the link heals within the grace window the client's tasks are never
    double-assigned and no takeover/termination churn happens.  A truly
    dead client still dies at ``limit`` + grace."""

    limit: float
    partition_grace_s: float = 0.0

    def allowance(self, ci) -> float:
        if ci.suspected_at is not None:
            return self.limit + self.partition_grace_s
        return self.limit


# ---------------------------------------------------------------------------
# budget
# ---------------------------------------------------------------------------
@dataclass
class BudgetPolicy:
    """Deny instance creation when the projected spend threatens ``cap``.

    Projection: cost accrued-or-committed so far (the CostMeter bills
    open instances at least to their minimum-billing commitment) plus
    ``reserve_s`` more seconds of the current burn rate and of the
    would-be instance's rate — i.e. scaling stops while enough budget
    remains to finish in-flight work.  On engines with a minimum billing
    commitment, set ``reserve_s`` at or above it so the would-be
    instance's own commitment is covered."""

    cap: float
    reserve_s: float = 30.0

    def allow_create(self, core, tick) -> bool:
        projected = tick.accrued_cost \
            + self.reserve_s * (tick.burn_rate + tick.client_rate)
        return projected <= self.cap


# ---------------------------------------------------------------------------
# cost accounting
# ---------------------------------------------------------------------------
class CostMeter:
    """Account of per-instance billing intervals, synced from an engine's
    ``billing_records()``: tuples ``(name, kind, rate, start, end|None)``
    with an optional sixth element ``min_end`` — the end of a minimum
    billing commitment already locked in by starting the instance.  An
    open record (``end is None``) is billed to ``max(now, min_end)``, so
    committed spend is visible to the budget policy before it elapses."""

    def __init__(self):
        # name -> (kind, rate, t0, t1, min_end)
        self._records: dict[str, tuple] = {}

    def sync(self, records) -> None:
        for name, kind, rate, start, end, *rest in records:
            self._records[name] = (kind, rate, start, end,
                                   rest[0] if rest else None)

    @staticmethod
    def _billed_end(t1, min_end, now: float) -> float:
        if t1 is not None:
            return t1
        return now if min_end is None else max(now, min_end)

    def rate_of(self, name: str, default: float = 1.0) -> float:
        rec = self._records.get(name)
        return rec[1] if rec is not None else default

    def accrued(self, now: float) -> float:
        return sum((self._billed_end(t1, me, now) - t0) * rate
                   for _, rate, t0, t1, me in self._records.values())

    def burn_rate(self, now: float) -> float:
        """Sum of the rates of instances still billing."""
        return sum(rate for _, rate, t0, t1, _ in self._records.values()
                   if t1 is None)

    def by_kind(self, now: float) -> dict:
        out: dict[str, float] = {}
        for kind, rate, t0, t1, me in self._records.values():
            out[kind] = out.get(kind, 0.0) \
                + (self._billed_end(t1, me, now) - t0) * rate
        return out

    def instance_seconds(self, now: float) -> float:
        return sum(self._billed_end(t1, me, now) - t0
                   for _, _, t0, t1, me in self._records.values())

    def summary(self, now: float) -> dict:
        return {
            "total": round(self.accrued(now), 6),
            "instance_seconds": round(self.instance_seconds(now), 6),
            "by_kind": {k: round(v, 6)
                        for k, v in sorted(self.by_kind(now).items())},
            "instances": len(self._records),
        }


# ---------------------------------------------------------------------------
# config -> policy factories (deterministic: rebuilt identically on restore)
# ---------------------------------------------------------------------------
def make_assign_policy(config) -> AssignPolicy:
    name = getattr(config, "assign_policy", "hardness")
    if name == "hardness":
        return HardnessOrderPolicy()
    if name == "backfill":
        return BatchBackfillPolicy(batch=getattr(config, "assign_batch", 4))
    raise ValueError(f"unknown assign_policy: {name!r}")


def make_scale_policy(config) -> ScalePolicy:
    name = getattr(config, "scale_policy", "fixed")
    if name == "fixed":
        return FixedFleetPolicy()
    if name == "demand":
        return DemandScalePolicy(
            workers_hint=getattr(config, "workers_hint", 1),
            idle_timeout_s=getattr(config, "idle_timeout_s", 5.0))
    raise ValueError(f"unknown scale_policy: {name!r}")


def make_budget_policy(config):
    cap = getattr(config, "budget_cap", None)
    if cap is None:
        return None
    return BudgetPolicy(cap=cap,
                        reserve_s=getattr(config, "budget_reserve_s", 30.0))


def make_liveness_policy(config) -> LivenessPolicy:
    return LivenessPolicy(
        limit=getattr(config, "health_update_limit", 10.0),
        partition_grace_s=getattr(config, "partition_grace_s", 0.0))
