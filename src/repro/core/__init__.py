# ExpoCloud — the paper's contribution, reproduced and grown:
#   space.py / experiment.py — declarative ParamSpace + @task + the
#                              one-call Experiment facade (RunHandle
#                              streams typed RunEvents)
#   engines.py               — name -> engine factory registry
#   task.py / hardness.py    — AbstractTask, hardness partial order, min_hard
#   scheduler.py / policy.py — pure SchedulerCore + pluggable policies
#   server.py / client.py    — pull-model primary/backup protocol shell
#   engine.py                — create/terminate/list engine abstraction
#   sim.py                   — deterministic virtual-clock cloud simulator
#   sweep.py                 — ML-cell bridge (arch x shape x mesh tasks)
from repro.core import engines
from repro.core.engine import (AbstractEngine, EngineUnavailable, GCEEngine,
                               LocalEngine, RateLimited, TPUPodEngine)
from repro.core.experiment import (CostTick, Experiment, InstanceCreated,
                                   InstancePreempted, InstanceTerminated,
                                   KillPrimary, Partition, RunDone, RunEvent,
                                   RunHandle, SpotWave, TaskPruned,
                                   TaskSolved, TaskTimedOut)
from repro.core.hardness import Hardness, MinHardSet
from repro.core.messages import Message, MsgType
from repro.core.policy import (AssignPolicy, BudgetPolicy, CostMeter,
                               ScalePolicy)
from repro.core.results import ResultsTable
from repro.core.server import Server, ServerConfig
from repro.core.shard import (ShardCoordinator, merge_cost_summaries,
                              merge_results, partition_tasks, pump_gossip)
from repro.core.sim import (InstanceType, ShardedSimCluster, SimCluster,
                            SimParams, SimTask)
from repro.core.space import Axis, ParamSpace, axis, task
from repro.core.task import AbstractTask

__all__ = [
    # unified experiment API (the front door)
    "Experiment", "RunHandle", "ParamSpace", "Axis", "axis", "task",
    "engines",
    # typed run events + chaos directives
    "RunEvent", "TaskSolved", "TaskPruned", "TaskTimedOut",
    "InstanceCreated", "InstanceTerminated", "InstancePreempted",
    "CostTick", "RunDone", "SpotWave", "Partition", "KillPrimary",
    # tasks / hardness / results
    "AbstractTask", "Hardness", "MinHardSet", "ResultsTable",
    # engines
    "AbstractEngine", "LocalEngine", "GCEEngine", "TPUPodEngine",
    "RateLimited", "EngineUnavailable",
    # simulator + server stack (advanced / deprecated direct wiring)
    "SimCluster", "ShardedSimCluster", "SimParams", "SimTask",
    "InstanceType", "Server", "ServerConfig", "Message", "MsgType",
    # sharded hierarchical scheduling (core.shard)
    "ShardCoordinator", "partition_tasks", "pump_gossip",
    "merge_results", "merge_cost_summaries",
    # policies + cost
    "AssignPolicy", "ScalePolicy", "BudgetPolicy", "CostMeter",
]
