# ExpoCloud — the paper's contribution, reproduced faithfully:
#   task.py / hardness.py   — AbstractTask, hardness partial order, min_hard
#   server.py / client.py   — pull-model primary/backup protocol
#   engine.py               — create/terminate/list engine abstraction
#   sim.py                  — deterministic virtual-clock cloud simulator
#   sweep.py                — ML-cell bridge (arch x shape x mesh tasks)
from repro.core.hardness import Hardness, MinHardSet
from repro.core.messages import Message, MsgType
from repro.core.server import Server, ServerConfig
from repro.core.task import AbstractTask

__all__ = ["Hardness", "MinHardSet", "Message", "MsgType", "Server",
           "ServerConfig", "AbstractTask"]
