"""Timing-trace record/replay for the discrete-event simulator.

Gent & Kotthoff ("Reliability of Computational Experiments on Virtualised
Hardware") make the case that cloud timings are themselves experimental
data: a run's materialization latencies, message delays, task runtimes and
preemption times characterize the platform as much as the results do.
This module captures those timings as a structured JSON **trace** and
replays them through the event engine deterministically:

  * ``SimParams(record_trace=True)`` attaches a ``TraceRecorder`` to the
    engine's network/worker/creation hooks; ``SimCluster.trace()`` returns
    the ``Trace`` and ``write_trace(path)`` persists it.
  * ``SimParams(trace=path_or_Trace)`` attaches a ``TraceReplayer``:
    per-route message delays, per-instance creation delays and per-task
    runtimes are drawn from the trace instead of the latency/jitter/RNG
    parameters, and recorded preemptions are re-injected as scripted
    kills — so a replayed run reproduces the original's results table
    row-for-row (asserted in ``benchmarks/sim_chaos_bench.py``).
  * ``trace_from_run`` builds a trace from a *real* run's artifacts (the
    per-client event logs and the engine's billing records — the same
    hooks Local/GCE engines already expose), so real-cluster timings can
    be replayed through the simulator.

Keys are chosen for replay stability, not compactness: message delays are
FIFO lists per directed route (the protocol consumes a route's messages
in deterministic order), creation delays are keyed by instance name
(names are allocated deterministically by the core) and runtimes by task
id (the hardness-sorted table position, stable for a fixed task list).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


def _route_key(route) -> str:
    return f"{route[0]}->{route[1]}"


@dataclass
class Trace:
    """A recorded run's timing data (JSON-serializable)."""

    message_delays: dict = field(default_factory=dict)   # "a->b" -> [delay]
    creation_delays: dict = field(default_factory=dict)  # name -> delay
    task_runtimes: dict = field(default_factory=dict)    # str(tid) -> seconds
    preemptions: list = field(default_factory=list)      # [(t, name)]
    meta: dict = field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps({
            "message_delays": self.message_delays,
            "creation_delays": self.creation_delays,
            "task_runtimes": self.task_runtimes,
            "preemptions": [[t, n] for t, n in self.preemptions],
            "meta": self.meta,
        }, indent=2)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def from_dict(cls, d: dict) -> Trace:
        return cls(
            message_delays={k: list(v)
                            for k, v in d.get("message_delays", {}).items()},
            creation_delays=dict(d.get("creation_delays", {})),
            task_runtimes=dict(d.get("task_runtimes", {})),
            preemptions=[(float(t), n) for t, n in d.get("preemptions", [])],
            meta=dict(d.get("meta", {})),
        )

    @classmethod
    def load(cls, path: str) -> Trace:
        with open(path) as f:
            return cls.from_dict(json.load(f))


def as_trace(trace) -> Trace:
    """Accepts a Trace, a dict, or a path to a trace JSON file."""
    if isinstance(trace, Trace):
        return trace
    if isinstance(trace, dict):
        return Trace.from_dict(trace)
    return Trace.load(trace)


class TraceRecorder:
    """Collects the timing samples of a live run (engine-attached)."""

    def __init__(self):
        self._delays: dict[str, list] = {}
        self._creations: dict[str, float] = {}
        self._runtimes: dict[str, float] = {}
        self._preemptions: list = []

    def record_delay(self, route, delay: float) -> None:
        self._delays.setdefault(_route_key(route), []).append(delay)

    def record_creation(self, name: str, delay: float) -> None:
        self._creations[name] = delay

    def record_runtime(self, tid, seconds: float) -> None:
        self._runtimes[str(tid)] = seconds

    def record_preemption(self, t: float, name: str) -> None:
        self._preemptions.append((t, name))

    def build(self, meta: dict | None = None) -> Trace:
        return Trace(
            message_delays={k: list(v) for k, v in self._delays.items()},
            creation_delays=dict(self._creations),
            task_runtimes=dict(self._runtimes),
            preemptions=list(self._preemptions),
            meta=dict(meta or {}),
        )


class TraceReplayer:
    """Feeds a recorded trace back through the engine hooks.

    Each delay list is consumed FIFO; when a sequence (or key) is
    exhausted the caller's default applies, so a trace recorded from a
    shorter or slightly different run degrades gracefully instead of
    failing."""

    def __init__(self, trace: Trace):
        self.trace = trace
        self._cursor: dict[str, int] = {}

    def next_delay(self, route, default: float) -> float:
        key = _route_key(route)
        seq = self.trace.message_delays.get(key)
        if not seq:
            return default
        i = self._cursor.get(key, 0)
        if i >= len(seq):
            return default
        self._cursor[key] = i + 1
        return seq[i]

    def creation_delay(self, name: str, default: float) -> float:
        return self.trace.creation_delays.get(name, default)

    def runtime(self, tid, default: float) -> float:
        return self.trace.task_runtimes.get(str(tid), default)

    def preemptions(self) -> list:
        return list(self.trace.preemptions)


def trace_from_run(events_by_client: dict, billing_records=None,
                   meta: dict | None = None) -> Trace:
    """Build a replayable trace from a *real* run's artifacts.

    ``events_by_client`` is the ``EventLog.snapshot()`` mapping (client ->
    [{"t", "kind", "body"}...]); task runtimes are reconstructed from the
    per-task ``started``/``done`` LOG events.  ``billing_records`` (the
    engine's ``billing_records()`` tuples) provide per-instance creation
    delays when the engine reports a requested-at time in ``meta``;
    otherwise creation delays are left to the replay defaults."""
    started: dict[int, float] = {}
    runtimes: dict[str, float] = {}
    for events in events_by_client.values():
        for e in events:
            body = e.get("body") or {}
            if not isinstance(body, dict):
                continue
            ev_name = body.get("event")
            if ev_name == "lifecycle":
                # combined per-wake form: start times under "started"
                for tid in body.get("started") or ():
                    started[tid] = e["t"]
                continue
            # clients batch lifecycle LOGs per wake ({"tids": [...]});
            # the single-tid form appears in pre-batching event logs
            tids = body.get("tids") if "tids" in body else (
                (body["tid"],) if "tid" in body else ())
            if ev_name == "started":
                for tid in tids:
                    started[tid] = e["t"]
            elif ev_name == "done":
                for tid in tids:
                    if tid in started:
                        runtimes[str(tid)] = e["t"] - started.pop(tid)
    trace = Trace(task_runtimes=runtimes, meta=dict(meta or {}))
    if billing_records:
        trace.meta["billing"] = [list(r) for r in billing_records]
    return trace
