"""The primary (and backup) server — the paper's core loop.

Task bookkeeping follows the paper exactly:
  * ``tasks``            — sorted non-decreasing hardness (lexicographic
                           order on the hardness tuple is a linear extension
                           of the componentwise partial order),
  * ``tasks_from_failed``— indices assigned to a failed client, re-assigned
                           with priority,
  * ``min_hard``         — Pareto-minimal antichain of timed-out hardnesses.

run-loop actions (paper §"The primary server" b):
  1. health update to the backup,
  2. handshakes from new instances,
  3. client messages (each forwarded to the backup),
  4. instance creation (backup precedence; exponential backoff),
  5. terminate unhealthy instances (+ reassign their tasks),
  6. output results when everything is done.

The same class runs as the backup server: it consumes the primary's
FORWARDed copies (popping the clients' direct copies), mirrors the
primary's replies on the backup channels, and takes over on primary
silence (SWAP_QUEUES + dangling-instance cleanup).
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field

from repro.core.hardness import Hardness, MinHardSet
from repro.core.messages import Message, MsgType
from repro.core.results import EventLog, ResultsTable


@dataclass
class ServerConfig:
    min_group_size: int = 0
    max_task_attempts: int = 3      # poison-task cap (beyond-paper)
    use_backup: bool = False
    max_clients: int = 4
    workers_hint: int = 1              # informational; pools size themselves
    health_update_limit: float = 10.0
    instance_max_non_active_time: float = 30.0
    create_backoff_init: float = 0.5
    create_backoff_max: float = 30.0
    health_interval: float = 1.0
    out_dir: str | None = None


@dataclass
class ClientInfo:
    name: str
    endpoint: object
    last_health: float
    srv_seq: int = 0                    # per-client logical send counter
    last_client_seq: int = -1           # highest processed client msg seq
    assigned: dict = field(default_factory=dict)   # tid -> task


# task status values
PENDING, ASSIGNED, DONE, TIMED_OUT, PRUNED, FAILED_POOL = (
    "pending", "assigned", "done", "timed_out", "pruned", "failed_pool")


class Server:
    def __init__(self, tasks, engine, config: ServerConfig | None = None,
                 name: str = "primary", role: str = "primary"):
        self.engine = engine
        self.config = config or ServerConfig()
        self.name = name
        self.role = role

        order = sorted(range(len(tasks)),
                       key=lambda i: tuple(tasks[i].hardness().values))
        self.tasks = [tasks[i] for i in order]        # hardness-sorted
        self.original_index = order                    # sorted pos -> orig pos
        self.status = [PENDING] * len(tasks)
        self.next_ptr = 0
        self.tasks_from_failed: list[int] = []
        self.min_hard = MinHardSet()
        self.results: dict[int, tuple] = {}
        self.attempts: dict[int, int] = {}

        self.clients: dict[str, ClientInfo] = {}
        self.events = EventLog()
        self.done = False
        self.final_results: ResultsTable | None = None

        # backup coordination
        self.backup_endpoint = None          # primary's channel to backup
        self.backup_name = None
        self.backup_last_health = None
        self.backup_pending = False
        self.frozen = False
        self.primary_endpoint = None         # backup's channel to primary
        self.primary_last_health = None
        self._direct_buffer: dict[str, list[Message]] = {}

        # instance creation backoff
        self._next_create_at = 0.0
        self._backoff = self.config.create_backoff_init
        self._client_counter = 0
        self._instance_birth: dict[str, float] = {}
        # server<->server heartbeats go out at health_interval cadence (the
        # same cadence clients use), not once per loop iteration — under the
        # event-driven simulator a per-step heartbeat would wake the peer,
        # whose step sends one back, pinging forever at latency granularity
        self._last_peer_health_sent = -1e18

    # ------------------------------------------------------------------
    def now(self) -> float:
        return self.engine.now()

    def send_to_client(self, ci: ClientInfo, mtype, body=None):
        msg = Message(mtype, self.name, body, srv_seq=ci.srv_seq)
        ci.srv_seq += 1
        # the endpoint can be gone already: a backup may learn of a client
        # whose instance the primary terminated while the notification was
        # in flight — the send just goes nowhere, like a deleted VM's queue
        if ci.endpoint is not None:
            ci.endpoint.send(msg)

    # ------------------------------------------------------------------
    # task assignment (paper §a)
    # ------------------------------------------------------------------
    def _next_tasks(self, n: int) -> list[tuple[int, object]]:
        out = []
        while self.tasks_from_failed and len(out) < n:
            tid = self.tasks_from_failed.pop(0)
            if self.status[tid] != FAILED_POOL:
                continue
            if self.min_hard.disqualifies(self.tasks[tid].hardness()):
                self.status[tid] = PRUNED
                continue
            out.append((tid, self.tasks[tid]))
        while self.next_ptr < len(self.tasks) and len(out) < n:
            tid = self.next_ptr
            self.next_ptr += 1
            if self.status[tid] != PENDING:
                continue
            if self.min_hard.disqualifies(self.tasks[tid].hardness()):
                self.status[tid] = PRUNED
                continue
            out.append((tid, self.tasks[tid]))
        return out

    def _has_assignable(self) -> bool:
        if any(self.status[t] == FAILED_POOL for t in self.tasks_from_failed):
            return True
        for tid in range(self.next_ptr, len(self.tasks)):
            if self.status[tid] == PENDING \
                    and not self.min_hard.disqualifies(
                        self.tasks[tid].hardness()):
                return True
        return False

    # ------------------------------------------------------------------
    # message handling (paper §c)
    # ------------------------------------------------------------------
    def process_client_message(self, msg: Message):
        cname = msg.sender
        ci = self.clients.get(cname)
        if ci is None:
            return
        ci.last_client_seq = max(ci.last_client_seq, msg.seq)
        t = msg.type
        if t == MsgType.HEALTH_UPDATE:
            ci.last_health = self.now()
        elif t == MsgType.REQUEST_TASKS:
            granted = self._next_tasks(msg.body["n"])
            if granted:
                for tid, task in granted:
                    self.status[tid] = ASSIGNED
                    ci.assigned[tid] = task
                # echo the request size so a partial grant still settles the
                # client's whole outstanding count (see Client._act)
                self.send_to_client(ci, MsgType.GRANT_TASKS,
                                    {"tasks": granted,
                                     "requested": msg.body["n"]})
            else:
                self.send_to_client(ci, MsgType.NO_FURTHER_TASKS)
        elif t == MsgType.RESULT:
            tid = msg.body["tid"]
            # Only ASSIGNED tasks may complete: a racy late result for a
            # task already TIMED_OUT/PRUNED (domino effect) or already DONE
            # (duplicate copy after takeover) must not corrupt the table.
            if self.status[tid] == ASSIGNED:
                self.results[tid] = tuple(msg.body["result"])
                self.status[tid] = DONE
            ci.assigned.pop(tid, None)
        elif t == MsgType.REPORT_HARD_TASK:
            tid = msg.body["tid"]
            h = Hardness(tuple(msg.body["hardness"]))
            self.status[tid] = TIMED_OUT
            ci.assigned.pop(tid, None)
            self.min_hard.add(h)
            self._apply_domino(h)
            for other in self.clients.values():
                self.send_to_client(other, MsgType.APPLY_DOMINO_EFFECT,
                                    {"hardness": h.values})
        elif t == MsgType.LOG:
            self.events.log(cname, self.now(), "LOG", msg.body)
        elif t == MsgType.EXCEPTION:
            self.events.log(cname, self.now(), "EXCEPTION", msg.body)
            tid = (msg.body or {}).get("tid")
            if tid is not None and self.status[tid] == ASSIGNED:
                ci.assigned.pop(tid, None)
                self.attempts[tid] = self.attempts.get(tid, 1) + 1
                if self.attempts[tid] > self.config.max_task_attempts:
                    # poison task: stop retrying (would livelock otherwise)
                    self.status[tid] = PRUNED
                else:
                    # worker crash: send the task back to the pool
                    self.status[tid] = FAILED_POOL
                    self.tasks_from_failed.append(tid)
        elif t == MsgType.BYE:
            self.events.log(cname, self.now(), "LOG", {"event": "bye"})
            self._drop_client(cname, terminate_instance=True)

    def _apply_domino(self, h: Hardness):
        """Mark all assigned/pending tasks dominated by h as pruned (their
        clients are terminating them; results will never arrive)."""
        for ci in self.clients.values():
            for tid in list(ci.assigned):
                if self.tasks[tid].hardness().geq(h):
                    if self.status[tid] == ASSIGNED:
                        self.status[tid] = PRUNED
                    ci.assigned.pop(tid, None)

    def _drop_client(self, cname: str, terminate_instance: bool,
                     reassign: bool = False):
        ci = self.clients.pop(cname, None)
        if ci is None:
            return
        if reassign:
            for tid in ci.assigned:
                if self.status[tid] == ASSIGNED:
                    self.status[tid] = FAILED_POOL
                    self.tasks_from_failed.append(tid)
        if terminate_instance and self.role == "primary":
            self.engine.terminate_instance(cname)
        if self.role == "primary" and self.backup_endpoint is not None:
            self.backup_endpoint.send(
                Message(MsgType.CLIENT_TERMINATED, self.name,
                        {"name": cname}))

    # ------------------------------------------------------------------
    # the run loop (paper §b)
    # ------------------------------------------------------------------
    def step(self):
        if self.role == "primary":
            self._step_primary()
        else:
            self._step_backup()

    def _step_primary(self):
        now = self.now()
        # 1. health update to the backup
        if self.backup_endpoint is not None \
                and now - self._last_peer_health_sent \
                >= self.config.health_interval:
            self.backup_endpoint.send(
                Message(MsgType.HEALTH_UPDATE, self.name))
            self._last_peer_health_sent = now

        # 2. handshakes (while frozen, only the backup's handshake is
        #    accepted — client handshakes are deferred, per the paper's
        #    "stops accepting handshake requests from new client instances")
        self._handle_handshakes()
        # poll backup health
        if self.backup_endpoint is not None:
            while True:
                m = self.backup_endpoint.poll()
                if m is None:
                    break
                if m.type == MsgType.HEALTH_UPDATE:
                    self.backup_last_health = now

        # 3. client messages (deferred entirely while frozen so the backup
        #    snapshot + forwarded stream is a consistent replay)
        if not self.frozen:
            for cname in list(self.clients):
                ci = self.clients.get(cname)
                if ci is None or ci.endpoint is None:
                    continue
                while True:
                    msg = ci.endpoint.poll()
                    if msg is None:
                        break
                    if self.backup_endpoint is not None:
                        self.backup_endpoint.send(
                            Message(MsgType.FORWARD, self.name,
                                    {"msg": msg}))
                    self.process_client_message(msg)

        # 4. instance creation
        self._maybe_create_instance(now)

        # 5. terminate unhealthy instances
        self._terminate_unhealthy(now)

        # 6. results
        self._check_done()

    def _handle_handshakes(self):
        todo = getattr(self, "_deferred_handshakes", [])
        self._deferred_handshakes = []
        while True:
            msg = self.engine.handshake_recv.poll()
            if msg is None:
                break
            todo.append(msg)
        for msg in todo:
            if msg.type != MsgType.HANDSHAKE:
                continue
            kind = msg.body["kind"]
            name = msg.sender
            if self.frozen and kind == "client":
                self._deferred_handshakes.append(msg)  # handled post-thaw
                continue
            pending = self.engine.pending.pop(name, None)
            if pending is None:
                continue
            if kind == "client":
                ci = ClientInfo(name, pending.primary_side, self.now())
                self.clients[name] = ci
                self.events.ensure(name)
                if self.backup_endpoint is not None:
                    self.backup_endpoint.send(
                        Message(MsgType.NEW_CLIENT, self.name,
                                {"name": name, "srv_seq": ci.srv_seq,
                                 "last_client_seq": ci.last_client_seq}))
            elif kind == "backup":
                self.backup_endpoint = pending.primary_side
                self.backup_name = name
                self.backup_last_health = self.now()
                self.backup_pending = False
                # register existing clients with the new backup
                for cname, ci in self.clients.items():
                    self.backup_endpoint.send(
                        Message(MsgType.NEW_CLIENT, self.name,
                                {"name": cname, "srv_seq": ci.srv_seq,
                                 "last_client_seq": ci.last_client_seq}))
                # unfreeze: clients may resume
                for ci in self.clients.values():
                    self.send_to_client(ci, MsgType.RESUME)
                self.frozen = False

    def _maybe_create_instance(self, now):
        if now < self._next_create_at:
            return
        from repro.core.engine import RateLimited

        try:
            if self.config.use_backup and self.backup_endpoint is None \
                    and not self.backup_pending:
                # freeze the world, snapshot, create the backup (paper §a)
                self.frozen = True
                for ci in self.clients.values():
                    self.send_to_client(ci, MsgType.STOP)
                snapshot = self.serialize_state()
                name = f"backup-{self._client_counter}"
                self._client_counter += 1
                self.engine.create_instance("backup", name, payload=snapshot)
                self.backup_pending = True
                self._instance_birth[name] = now
            elif self._has_assignable() \
                    and len(self.clients) + len(self.engine.pending) \
                    < self.config.max_clients:
                name = f"client-{self._client_counter}"
                self._client_counter += 1
                self.engine.create_instance("client", name)
                self._instance_birth[name] = now
            else:
                return
            self._backoff = self.config.create_backoff_init
            self._next_create_at = now + self._backoff
        except RateLimited:
            self._backoff = min(self._backoff * 2,
                                self.config.create_backoff_max)
            self._next_create_at = now + self._backoff
            if self.frozen and self.backup_pending is False:
                # failed to even create the backup: unfreeze and retry later
                for ci in self.clients.values():
                    self.send_to_client(ci, MsgType.RESUME)
                self.frozen = False

    def _terminate_unhealthy(self, now):
        limit = self.config.health_update_limit
        for cname, ci in list(self.clients.items()):
            if now - ci.last_health > limit:
                self.events.log(cname, now, "LOG", {"event": "unhealthy"})
                self.engine.terminate_instance(cname)
                self._drop_client(cname, terminate_instance=False,
                                  reassign=True)
        # pending instances that never handshook
        max_na = self.config.instance_max_non_active_time
        for name, pending in list(self.engine.pending.items()):
            if now - pending.created_at > max_na:
                self.engine.terminate_instance(name)
                self.engine.pending.pop(name, None)
                if pending.kind == "backup":
                    self.backup_pending = False
                    if self.frozen:
                        for ci in self.clients.values():
                            self.send_to_client(ci, MsgType.RESUME)
                        self.frozen = False
        # backup health
        if self.backup_endpoint is not None \
                and self.backup_last_health is not None \
                and now - self.backup_last_health > limit:
            self.engine.terminate_instance(self.backup_name)
            self.backup_endpoint = None
            self.backup_name = None
            self.backup_last_health = None

    def _check_done(self):
        if self.done:
            return
        active = any(s in (ASSIGNED,) for s in self.status)
        if active or self._has_assignable():
            return
        # no assignable work, nothing in flight: sweep survivors
        for tid, s in enumerate(self.status):
            if s in (PENDING, FAILED_POOL):
                self.status[tid] = PRUNED
        self.done = True
        self.final_results = self.output_results()
        if self.config.out_dir:
            self.final_results.write(self.config.out_dir)
            self.events.write(self.config.out_dir)

    # ------------------------------------------------------------------
    def output_results(self) -> ResultsTable:
        return ResultsTable.build(
            tasks=self.tasks,
            original_index=self.original_index,
            status=self.status,
            results=self.results,
            min_group_size=self.config.min_group_size,
        )

    # ------------------------------------------------------------------
    # backup-server machinery (paper §fault tolerance)
    # ------------------------------------------------------------------
    def serialize_state(self) -> bytes:
        return pickle.dumps({
            "tasks": self.tasks,
            "original_index": self.original_index,
            "status": self.status,
            "next_ptr": self.next_ptr,
            "tasks_from_failed": self.tasks_from_failed,
            "min_hard": self.min_hard.snapshot(),
            "results": self.results,
            "clients": {c: (ci.srv_seq, ci.last_client_seq)
                        for c, ci in self.clients.items()},
            "config": self.config,
            "events": self.events.snapshot(),
        })

    @classmethod
    def from_snapshot(cls, blob: bytes, engine, name: str = "backup"):
        st = pickle.loads(blob)
        srv = cls.__new__(cls)
        srv.engine = engine
        srv.config = st["config"]
        srv.name = name
        srv.role = "backup"
        srv.tasks = st["tasks"]
        srv.original_index = st["original_index"]
        srv.status = st["status"]
        srv.next_ptr = st["next_ptr"]
        srv.tasks_from_failed = list(st["tasks_from_failed"])
        srv.min_hard = MinHardSet()
        srv.min_hard.restore(st["min_hard"])
        srv.results = dict(st["results"])
        srv.clients = {}
        srv._snapshot_clients = st["clients"]
        srv.events = EventLog()
        srv.events.restore(st["events"])
        srv.done = False
        srv.final_results = None
        srv.backup_endpoint = None
        srv.backup_name = None
        srv.backup_last_health = None
        srv.backup_pending = False
        srv.frozen = False
        srv.primary_endpoint = None
        srv.primary_last_health = None
        srv._direct_buffer = {}
        srv._next_create_at = 0.0
        srv._backoff = srv.config.create_backoff_init
        srv._client_counter = 10_000   # avoid name collisions with primary
        srv._instance_birth = {}
        srv._last_peer_health_sent = -1e18
        return srv

    def backup_bootstrap(self, primary_endpoint, handshake_send):
        """assume_backup_role: connect to the primary, register clients'
        backup channels, handshake."""
        self.primary_endpoint = primary_endpoint
        self.primary_last_health = self.now()
        for cname, (srv_seq, last_seq) in self._snapshot_clients.items():
            ep = self.engine.backup_endpoint(cname)
            ci = ClientInfo(cname, ep, self.now(), srv_seq=srv_seq,
                            last_client_seq=last_seq)
            self.clients[cname] = ci
            self._direct_buffer.setdefault(cname, [])
        handshake_send.send(Message(MsgType.HANDSHAKE, self.name,
                                    body={"kind": "backup"}))

    def _step_backup(self):
        now = self.now()
        # health to primary
        if now - self._last_peer_health_sent >= self.config.health_interval:
            self.primary_endpoint.send(
                Message(MsgType.HEALTH_UPDATE, self.name))
            self._last_peer_health_sent = now
        # messages from the primary
        while True:
            m = self.primary_endpoint.poll()
            if m is None:
                break
            if m.type == MsgType.HEALTH_UPDATE:
                self.primary_last_health = now
            elif m.type == MsgType.FORWARD:
                inner: Message = m.body["msg"]
                self._pop_direct(inner)
                self.process_client_message(inner)
            elif m.type == MsgType.NEW_CLIENT:
                b = m.body
                ep = self.engine.backup_endpoint(b["name"])
                self.clients[b["name"]] = ClientInfo(
                    b["name"], ep, now, srv_seq=b["srv_seq"],
                    last_client_seq=b["last_client_seq"])
                self._direct_buffer.setdefault(b["name"], [])
                self.events.ensure(b["name"])
            elif m.type == MsgType.CLIENT_TERMINATED:
                self.clients.pop(m.body["name"], None)
                self._direct_buffer.pop(m.body["name"], None)
        # direct copies from clients -> buffer
        for cname, ci in list(self.clients.items()):
            if ci.endpoint is None:
                continue   # instance deleted while its registration flew
            while True:
                m = ci.endpoint.poll()
                if m is None:
                    break
                if m.seq <= ci.last_client_seq:
                    continue  # processed by primary before the snapshot
                self._direct_buffer.setdefault(cname, []).append(m)
                if m.type == MsgType.HEALTH_UPDATE:
                    ci.last_health = now
        # primary failure -> take over
        if now - self.primary_last_health > self.config.health_update_limit:
            self._take_over()

    def _pop_direct(self, inner: Message):
        buf = self._direct_buffer.get(inner.sender)
        if not buf:
            return
        self._direct_buffer[inner.sender] = [
            m for m in buf if m.key() != inner.key()]

    def _take_over(self):
        """The backup becomes the primary (paper §c)."""
        self.role = "primary"
        self.name = "primary*"
        # swap queues on every client via their (old) primary channels; the
        # engine rotates the channel registry (old backup link -> primary
        # link) and mints a fresh backup link per client, shipped inside
        # SWAP_QUEUES — a later backup must not attach to the endpoint this
        # server now polls, or it would steal client messages
        rotate = getattr(self.engine, "rotate_client_channels", None)
        for cname, ci in self.clients.items():
            ep = self.engine.primary_endpoints(cname)
            new_backup = rotate(cname) if rotate is not None else None
            if ep is not None:
                ep.send(Message(MsgType.SWAP_QUEUES, self.name,
                                {"new_backup": new_backup}))
        # process buffered direct messages in order
        for cname in list(self._direct_buffer):
            ci = self.clients.get(cname)
            if ci is None:
                continue
            for m in sorted(self._direct_buffer.pop(cname, []),
                            key=lambda m: m.seq):
                self.process_client_message(m)
        # dangling-instance cleanup: delete instances with no client object
        known = set(self.clients) | {self.name}
        for iname in self.engine.list_instances():
            if iname not in known and not iname.startswith("backup"):
                self.engine.terminate_instance(iname)
        self.backup_endpoint = None
        self.backup_name = None
        self.backup_pending = False

    # ------------------------------------------------------------------
    def next_wake(self, now: float) -> float:
        """Earliest future time this server needs attention absent incoming
        messages: the next heartbeat tick (which also bounds how late the
        liveness checks run) or a pending instance-creation backoff expiry.
        Scheduling hint for the discrete-event simulator only."""
        nxt = now + self.config.health_interval
        if self.role == "primary" and now < self._next_create_at:
            nxt = min(nxt, self._next_create_at)
        return max(nxt, now + 1e-6)

    # ------------------------------------------------------------------
    def run(self, poll_sleep: float = 0.02, stop_when_done: bool = True):
        """Drive the loop with the engine's real clock (LocalEngine/GCE).
        The paper keeps servers alive after results are output; callers who
        want that behaviour pass stop_when_done=False and stop externally.
        """
        import time as _t

        while True:
            self.step()
            if self.done and stop_when_done:
                return self.final_results
            _t.sleep(poll_sleep)
