"""The primary (and backup) server — a thin shell around SchedulerCore.

The scheduling brain lives in ``repro.core.scheduler`` (pure, typed
events in / typed effects out) with swappable policies in
``repro.core.policy``.  This module is the transport/engine shell:

  * the **primary** polls real channels, feeds each client message and a
    periodic ``Tick`` into the core, and executes the emitted effects
    (sends, instance creation with exponential backoff, terminations),
    plus the engine-facing plumbing the core never sees: handshakes,
    backup creation (freeze -> snapshot -> create), pending-instance
    reaping and peer heartbeats;
  * the **backup** replays the primary's FORWARDed copies into its own
    restored core (mirroring replies on the backup channels), buffers
    the clients' direct copies, and takes over on primary silence —
    takeover is "replay the same event stream into the same core";
  * the ``CostMeter`` is synced from the engine's billing records and
    surfaces as cost columns in the results table.

run-loop actions (paper §"The primary server" b):
  1. health update to the backup,
  2. handshakes from new instances,
  3. client messages (each forwarded to the backup),
  4. instance creation (backup precedence; exponential backoff),
  5. terminate unhealthy instances (+ reassign their tasks),
  6. output results when everything is done.
"""
from __future__ import annotations

import pickle
import warnings

from repro.core.hardness import Hardness
from repro.core.messages import Message, MsgType
from repro.core.policy import CostMeter
from repro.core.results import ResultsTable
from repro.core.scheduler import (ASSIGNED, DONE, FAILED_POOL, PENDING,
                                  PRUNED, TIMED_OUT, ClientInfo,
                                  ClientMessage, CreateInstance,
                                  SchedulerCore, Send, ServerConfig,
                                  TerminateInstance, Tick)

__all__ = [
    "Server", "ServerConfig", "ClientInfo",
    "PENDING", "ASSIGNED", "DONE", "TIMED_OUT", "PRUNED", "FAILED_POOL",
]

# instance-name counter floor applied after restoring any snapshot: the
# snapshotting primary may allocate more names before this server acts
# on the restored core, and colliding instance names would cross wires
_RESTORE_NAME_FLOOR = 10_000


def _restore_core(blob: bytes):
    """Restore a serialized core (see ``Server.serialize_state``).
    Returns (core, replication-stream position)."""
    st = pickle.loads(blob)
    core = SchedulerCore.restore(st["core"])
    core._client_counter = max(core._client_counter, _RESTORE_NAME_FLOOR)
    return core, st.get("rep", 0)


class Server:
    def __init__(self, tasks, engine, config: ServerConfig | None = None,
                 name: str = "primary", role: str = "primary",
                 _internal: bool = False):
        if not _internal:
            warnings.warn(
                "hand-wiring Server(tasks, engine, config) is deprecated; "
                "use repro.core.Experiment(tasks, engine=...) — the facade "
                "wires engines, policies and results identically across "
                "sim/local/gce/tpu", DeprecationWarning, stacklevel=2)
        self.engine = engine
        self.config = config or ServerConfig()
        self.name = name
        self.role = role
        self.core = SchedulerCore(tasks, self.config)
        self._init_shell_state()

    def _init_shell_state(self):
        self.cost_meter = CostMeter()
        self._final_results: ResultsTable | None = None
        self._results_written = False

        # backup coordination
        self.backup_endpoint = None          # primary's channel to backup
        self.backup_name = None
        self.backup_last_health = None
        self.backup_pending = False
        self.frozen = False
        self.primary_endpoint = None         # backup's channel to primary
        self.primary_last_health = None
        self._direct_buffer: dict[str, list[Message]] = {}
        self._deferred_handshakes: list[Message] = []

        # replication-stream numbering: every state-bearing message to the
        # backup (FORWARD / NEW_CLIENT / CLIENT_TERMINATED / BROADCAST)
        # carries a contiguous counter, so a backup behind a partition
        # detects the gap on the first message that gets through and
        # resyncs from a fresh snapshot instead of silently split-braining
        self._rep_seq = 0                    # primary: next number to send
        self._expect_rep = 0                 # backup: next number expected
        self._resync_pending = False
        self._last_resync_req = -1e18

        # client-link partition tracking (LinkLost/LinkHealed into the core)
        self._links_down: set[str] = set()
        self._last_link_poll = -1e18
        self._peer_was_down = False

        # ready-set polling: recv-wire -> client name (and the reverse),
        # so engines that track deliveries let us drain only endpoints
        # with something due instead of sweeping every client
        self._wire_owner: dict = {}
        self._owned_wires: dict[str, object] = {}

        # instance creation backoff
        self._next_create_at = 0.0
        self._backoff = self.config.create_backoff_init
        # server<->server heartbeats go out at health_interval cadence (the
        # same cadence clients use), not once per loop iteration — under the
        # event-driven simulator a per-step heartbeat would wake the peer,
        # whose step sends one back, pinging forever at latency granularity
        self._last_peer_health_sent = -1e18

    # ------------------------------------------------------------------
    # core-state delegation (the core owns all scheduling state)
    # ------------------------------------------------------------------
    @property
    def clients(self) -> dict[str, ClientInfo]:
        return self.core.clients

    @property
    def tasks(self):
        return self.core.tasks

    @property
    def original_index(self):
        return self.core.original_index

    @property
    def status(self):
        return self.core.status

    @property
    def next_ptr(self):
        return self.core.next_ptr

    @property
    def tasks_from_failed(self):
        return self.core.tasks_from_failed

    @property
    def min_hard(self):
        return self.core.min_hard

    @property
    def results(self):
        return self.core.results

    @property
    def attempts(self):
        return self.core.attempts

    @property
    def events(self):
        return self.core.events

    @property
    def done(self) -> bool:
        return self.core.done

    @property
    def final_results(self):
        """Final results table, built lazily on first access once the
        core is done — table building is reporting, not scheduling, so
        it stays out of the run loop (and out of the fleet benchmark's
        measured window)."""
        if self._final_results is None and self.core.done:
            self._final_results = self.output_results()
        return self._final_results

    # ------------------------------------------------------------------
    def now(self) -> float:
        return self.engine.now()

    # ------------------------------------------------------------------
    # ready-set endpoint bookkeeping
    # ------------------------------------------------------------------
    def _own_endpoint(self, ci: ClientInfo):
        wire = getattr(ci.endpoint, "recv_wire", None)
        if wire is not None:
            self._wire_owner[wire] = ci.name
            self._owned_wires[ci.name] = wire

    def _disown_endpoint(self, cname: str):
        wire = self._owned_wires.pop(cname, None)
        if wire is not None:
            self._wire_owner.pop(wire, None)

    def _mark_drained(self, ep):
        """Re-arm or clear an endpoint's ready mark after an
        unconditional drain (server<->server wires are polled directly,
        outside the ready-set path)."""
        drained = getattr(self.engine, "endpoint_drained", None)
        if drained is not None and ep is not None:
            drained(ep)

    def _drain_ready(self, now: float, drain_one):
        """Drain client endpoints with pending deliveries.  ``drain_one``
        is called with each ClientInfo whose endpoint must be polled; with
        an engine that tracks deliveries only the due endpoints are
        visited, otherwise every client is swept."""
        ready = getattr(self.engine, "ready_wires", None)
        drained = getattr(self.engine, "endpoint_drained", None)
        if ready is not None:
            for wire in ready(now):
                cname = self._wire_owner.get(wire)
                if cname is None:
                    continue           # another server's wire
                ci = self.core.clients.get(cname)
                if ci is None or ci.endpoint is None:
                    continue
                drain_one(ci)
                if drained is not None:
                    drained(ci.endpoint)
        else:
            for cname in list(self.core.clients):
                ci = self.core.clients.get(cname)
                if ci is None or ci.endpoint is None:
                    continue
                drain_one(ci)

    # ------------------------------------------------------------------
    # effect execution
    # ------------------------------------------------------------------
    def _send_backup(self, mtype, body: dict):
        """Numbered send on the replication stream (primary -> backup)."""
        if self.backup_endpoint is None:
            return
        body = dict(body)
        body["rep"] = self._rep_seq
        self._rep_seq += 1
        self.backup_endpoint.send(Message(mtype, self.name, body))

    def _apply(self, eff, now: float):
        if isinstance(eff, Send):
            ci = self.core.clients.get(eff.client)
            # the endpoint can be gone already: a backup may learn of a
            # client whose instance the primary terminated while the
            # notification was in flight — the send just goes nowhere,
            # like a deleted VM's queue
            if ci is not None and ci.endpoint is not None:
                ci.endpoint.send(Message(eff.mtype, self.name, eff.body,
                                         srv_seq=eff.srv_seq,
                                         ctrl_seq=eff.ctrl_seq))
        elif isinstance(eff, TerminateInstance):
            self._disown_endpoint(eff.name)
            if self.role == "primary":
                self.engine.terminate_instance(eff.name)
                self._send_backup(MsgType.CLIENT_TERMINATED,
                                  {"name": eff.name})
        elif isinstance(eff, CreateInstance):
            self._execute_create(eff, now)

    def _execute_create(self, eff: CreateInstance, now: float):
        from repro.core.engine import RateLimited

        try:
            self.engine.create_instance(eff.kind, eff.name)
            self._backoff = self.config.create_backoff_init
            self._next_create_at = now + self._backoff
        except RateLimited:
            self._backoff = min(self._backoff * 2,
                                self.config.create_backoff_max)
            self._next_create_at = now + self._backoff

    def process_client_message(self, msg: Message):
        now = self.now()
        for eff in self.core.on_message(msg, now):
            self._apply(eff, now)

    def _broadcast(self, mtype, now: float):
        for eff in self.core.control_broadcast(mtype):
            self._apply(eff, now)
        # the backup mirrors the broadcast (consuming the same ctrl_seq in
        # its own core and re-sending on the backup channels — the clients
        # dedup, and a takeover's ctrl counter stays aligned)
        self._send_backup(MsgType.BROADCAST, {"mtype": mtype})

    def apply_gossip(self, hardness_values) -> int:
        """Inject a batch of cross-shard hardnesses (ShardCoordinator
        gossip) into this server's core and notify its clients — one
        counterless message per client for the whole batch.  Replicated
        to the backup via the BROADCAST replication notice — gossip never
        arrives as a FORWARDable client message, so this is its only path
        into the mirror.  Returns the number of hardnesses that grew the
        frontier (i.e. pruned something new here)."""
        now = self.now()
        retained, effects = self.core.gossip_hardness(
            [Hardness(tuple(hv)) for hv in hardness_values])
        if not retained:
            return 0
        for eff in effects:
            self._apply(eff, now)
        self._send_backup(MsgType.BROADCAST,
                          {"mtype": MsgType.APPLY_DOMINO_EFFECT,
                           "body": {"hardnesses": list(retained)}})
        return len(retained)

    # ------------------------------------------------------------------
    # the run loop (paper §b)
    # ------------------------------------------------------------------
    def step(self):
        if self.role == "primary":
            self._step_primary()
        else:
            self._step_backup()

    def _step_primary(self):
        now = self.now()
        # 1. health update to the backup
        if self.backup_endpoint is not None \
                and now - self._last_peer_health_sent \
                >= self.config.health_interval:
            self.backup_endpoint.send(
                Message(MsgType.HEALTH_UPDATE, self.name))
            self._last_peer_health_sent = now

        # 2. handshakes (while frozen, only the backup's handshake is
        #    accepted — client handshakes are deferred, per the paper's
        #    "stops accepting handshake requests from new client instances")
        self._handle_handshakes()
        # poll backup health (and resync requests after a partition)
        if self.backup_endpoint is not None:
            while True:
                m = self.backup_endpoint.poll()
                if m is None:
                    break
                if m.type == MsgType.HEALTH_UPDATE:
                    self.backup_last_health = now
                elif m.type == MsgType.RESYNC_REQUEST:
                    # the backup missed part of the replication stream
                    # (partitioned pb link): ship a fresh snapshot — it
                    # re-bases on it instead of drifting or split-braining
                    self.backup_endpoint.send(
                        Message(MsgType.SYNC_STATE, self.name,
                                {"state": self.serialize_state()}))
            self._mark_drained(self.backup_endpoint)

        # client-link partition detection -> typed core events
        self._poll_client_links(now)

        # 3. client messages (deferred entirely while frozen so the backup
        #    snapshot + forwarded stream is a consistent replay); engines
        #    with delivery tracking let us visit only endpoints with a
        #    delivery due (ready-set polling) instead of sweeping all
        if not self.frozen:
            self._drain_ready(now, self._drain_primary_endpoint)

        # 4. instance creation (backup takes precedence) + policy tick
        can_create = now >= self._next_create_at
        if can_create and self.config.use_backup \
                and self.backup_endpoint is None and not self.backup_pending:
            self._create_backup(now)
            can_create = False
        for eff in self.core.on_tick(self._make_tick(now, can_create)):
            self._apply(eff, now)

        # 5. reap pending instances that never handshook; backup health
        self._reap_pending(now)
        self._check_backup_health(now)

        # 6. results — the table itself builds lazily on first access of
        #    ``final_results`` (reporting, not scheduling); only the
        #    output-folder side effect stays in the loop
        if self.core.done and self.config.out_dir \
                and not self._results_written:
            self._results_written = True
            self.final_results.write(self.config.out_dir)
            self.core.events.write(self.config.out_dir)

    def _drain_primary_endpoint(self, ci: ClientInfo):
        # the whole burst goes through core.handle_batch as ONE wake:
        # per-client ACK effects coalesce into a single send.  Each
        # message is still FORWARDed individually — the backup replays
        # them one at a time, which is exactly why ACKs are counterless
        # (see SchedulerCore.handle_batch)
        now = self.now()
        batch: list = []
        while True:
            msg = ci.endpoint.poll()
            if msg is None:
                break
            self._send_backup(MsgType.FORWARD, {"msg": msg})
            batch.append(ClientMessage(msg, now))
        if batch:
            for eff in self.core.handle_batch(batch):
                self._apply(eff, now)

    def _poll_client_links(self, now: float):
        """Diff the engine's link-state view of this server's client links
        (at heartbeat cadence) into LinkLost/LinkHealed core events, so
        liveness can grant partition grace.  Engines without a fault plane
        (Local/GCE) simply never report a partition."""
        down_fn = getattr(self.engine, "link_down", None)
        if down_fn is None \
                or now - self._last_link_poll < self.config.health_interval:
            return
        self._last_link_poll = now
        # fleet-scale fast path: nothing is partitioned anywhere and no
        # link is currently suspected — skip the O(clients) sweep
        faults = getattr(self.engine, "faults_possible", None)
        if faults is not None and not self._links_down and not faults():
            return
        label = "primary" if self.role == "primary" else "backup"
        for cname in list(self.core.clients):
            down = down_fn(label, cname)
            if down and cname not in self._links_down:
                self._links_down.add(cname)
                self.core.on_link_lost(cname, now)
            elif not down and cname in self._links_down:
                self._links_down.discard(cname)
                self.core.on_link_healed(cname, now)

    def _make_tick(self, now: float, can_create: bool) -> Tick:
        pending_map = getattr(self.engine, "pending", None) or {}
        pending = len(pending_map)
        pending_clients = sum(
            1 for p in pending_map.values()
            if getattr(p, "kind", "client") == "client")
        accrued = burn = 0.0
        client_rate = 1.0
        if self.config.budget_cap is not None:
            self._sync_meter()
            accrued = self.cost_meter.accrued(now)
            burn = self.cost_meter.burn_rate(now)
            rate_fn = getattr(self.engine, "cost_rate", None)
            if rate_fn is not None:
                client_rate = rate_fn("client")
        return Tick(now, pending_instances=pending,
                    pending_clients=pending_clients, can_create=can_create,
                    accrued_cost=accrued, burn_rate=burn,
                    client_rate=client_rate)

    def _sync_meter(self):
        records = getattr(self.engine, "billing_records", None)
        if records is not None:
            self.cost_meter.sync(records())

    def _handle_handshakes(self):
        todo = self._deferred_handshakes
        self._deferred_handshakes = []
        while True:
            msg = self.engine.handshake_recv.poll()
            if msg is None:
                break
            todo.append(msg)
        for msg in todo:
            if msg.type != MsgType.HANDSHAKE:
                continue
            kind = msg.body["kind"]
            name = msg.sender
            if self.frozen and kind == "client":
                self._deferred_handshakes.append(msg)  # handled post-thaw
                continue
            pending = self.engine.pending.pop(name, None)
            if pending is None:
                continue
            if kind == "client":
                ci = self.core.client_joined(name, self.now(),
                                             endpoint=pending.primary_side)
                self._own_endpoint(ci)
                self._send_backup(MsgType.NEW_CLIENT,
                                  {"name": name, "srv_seq": ci.srv_seq,
                                   "last_client_seq": ci.last_client_seq})
            elif kind == "backup":
                self.backup_endpoint = pending.primary_side
                self.backup_name = name
                self.backup_last_health = self.now()
                self.backup_pending = False
                # register existing clients with the new backup (it starts
                # expecting rep numbers from the counter embedded in the
                # snapshot it restored, which is exactly where we are)
                for cname, ci in self.core.clients.items():
                    self._send_backup(MsgType.NEW_CLIENT,
                                      {"name": cname, "srv_seq": ci.srv_seq,
                                       "last_client_seq": ci.last_client_seq})
                # unfreeze: clients may resume
                self._broadcast(MsgType.RESUME, self.now())
                self.frozen = False

    def _create_backup(self, now: float):
        """Freeze the world, snapshot, create the backup (paper §a)."""
        from repro.core.engine import RateLimited

        try:
            self.frozen = True
            self._broadcast(MsgType.STOP, now)
            snapshot = self.serialize_state()
            name = self.core.alloc_instance_name("backup")
            self.engine.create_instance("backup", name, payload=snapshot)
            self.backup_pending = True
            self._backoff = self.config.create_backoff_init
            self._next_create_at = now + self._backoff
        except RateLimited:
            self._backoff = min(self._backoff * 2,
                                self.config.create_backoff_max)
            self._next_create_at = now + self._backoff
            if self.frozen and self.backup_pending is False:
                # failed to even create the backup: unfreeze and retry later
                self._broadcast(MsgType.RESUME, now)
                self.frozen = False

    def _reap_pending(self, now: float):
        max_na = self.config.instance_max_non_active_time
        for name, pending in list(self.engine.pending.items()):
            if now - pending.created_at > max_na:
                self.engine.terminate_instance(name)
                self.engine.pending.pop(name, None)
                if pending.kind == "backup":
                    self.backup_pending = False
                    if self.frozen:
                        self._broadcast(MsgType.RESUME, now)
                        self.frozen = False

    def _peer_link_down(self) -> bool:
        down_fn = getattr(self.engine, "link_down", None)
        return down_fn is not None and down_fn("primary", "backup")

    def _peer_liveness(self, now: float, last_health):
        """Liveness allowance for the server peer (the pb link), shared by
        backup reaping and takeover: silence behind a *known* partition
        gets partition_grace_s (it explains the silence — killing/taking
        over a live peer would lose state or split-brain), and a heal
        restarts the health window (the peer's first post-heal heartbeat
        may still be in flight).  Returns (limit, last_health)."""
        limit = self.config.health_update_limit
        down = self._peer_link_down()
        if down:
            limit += self.config.partition_grace_s
        elif self._peer_was_down and last_health is not None:
            last_health = max(last_health, now)
        self._peer_was_down = down
        return limit, last_health

    def _check_backup_health(self, now: float):
        limit, self.backup_last_health = \
            self._peer_liveness(now, self.backup_last_health)
        if self.backup_endpoint is not None \
                and self.backup_last_health is not None \
                and now - self.backup_last_health > limit:
            self.engine.terminate_instance(self.backup_name)
            self.backup_endpoint = None
            self.backup_name = None
            self.backup_last_health = None

    # ------------------------------------------------------------------
    def output_results(self) -> ResultsTable:
        now = self.now()
        self._sync_meter()
        task_costs = {
            tid: (t1 - t0) * self.cost_meter.rate_of(cname)
            for tid, (cname, t0, t1) in self.core.task_spans.items()}
        return ResultsTable.build(
            tasks=self.core.tasks,
            original_index=self.core.original_index,
            status=self.core.status,
            results=self.core.results,
            min_group_size=self.config.min_group_size,
            task_costs=task_costs,
            cost=self.cost_meter.summary(now),
        )

    # ------------------------------------------------------------------
    # backup-server machinery (paper §fault tolerance)
    # ------------------------------------------------------------------
    def serialize_state(self) -> bytes:
        # "rep" pins where the replication stream stands at snapshot time:
        # the restoring backup expects the next numbered message from here
        return pickle.dumps({"core": self.core.snapshot(),
                             "rep": self._rep_seq})

    @classmethod
    def from_snapshot(cls, blob: bytes, engine, name: str = "backup"):
        srv = cls.__new__(cls)
        srv.engine = engine
        srv.core, expect_rep = _restore_core(blob)
        srv.config = srv.core.config
        srv.name = name
        srv.role = "backup"
        srv._init_shell_state()
        srv._expect_rep = expect_rep
        return srv

    @classmethod
    def resume_primary(cls, blob: bytes, engine, name: str = "primary"):
        """Resume an interrupted run from a serialized snapshot as a fresh
        *primary* on a fresh engine: solved results and pruning state are
        kept; clients of the old fleet are gone, so their in-flight
        assignments are requeued (at-least-once — a task that finished
        but whose RESULT missed the snapshot re-runs)."""
        srv = cls.from_snapshot(blob, engine, name=name)
        srv.role = "primary"
        now = srv.now()
        for cname in list(srv.core.clients):
            # effects are dropped: the old instances don't exist here
            srv.core.drop_client(cname, now, reassign=True)
        return srv

    def backup_bootstrap(self, primary_endpoint, handshake_send):
        """assume_backup_role: connect to the primary, register clients'
        backup channels, handshake."""
        self.primary_endpoint = primary_endpoint
        self.primary_last_health = self.now()
        for cname, ci in self.core.clients.items():
            ci.endpoint = self.engine.backup_endpoint(cname)
            ci.last_health = self.now()     # liveness clock starts here
            self._own_endpoint(ci)
            self._direct_buffer.setdefault(cname, [])
        handshake_send.send(Message(MsgType.HANDSHAKE, self.name,
                                    body={"kind": "backup"}))

    # message types whose loss desyncs the backup's mirror — all carry a
    # contiguous "rep" number so the first one through after a partition
    # exposes the gap
    _REPLICATED = (MsgType.FORWARD, MsgType.NEW_CLIENT,
                   MsgType.CLIENT_TERMINATED, MsgType.BROADCAST)

    def _request_resync(self, now: float):
        self._resync_pending = True
        self._last_resync_req = now
        self.primary_endpoint.send(
            Message(MsgType.RESYNC_REQUEST, self.name))

    def _apply_sync_state(self, blob: bytes, now: float):
        """Re-base the mirror on a fresh primary snapshot (post-partition
        recovery): restore the core, re-own the clients' backup channels
        and drop buffered direct copies the snapshot already covers."""
        self.core, self._expect_rep = _restore_core(blob)
        self._resync_pending = False
        self._wire_owner.clear()
        self._owned_wires.clear()
        for cname, ci in self.core.clients.items():
            ci.endpoint = self.engine.backup_endpoint(cname)
            ci.last_health = now
            self._own_endpoint(ci)
            buf = self._direct_buffer.get(cname, [])
            self._direct_buffer[cname] = [
                m for m in buf if m.seq > ci.last_client_seq]
        for cname in list(self._direct_buffer):
            if cname not in self.core.clients:
                self._direct_buffer.pop(cname)

    def _step_backup(self):
        now = self.now()
        # health to primary
        if now - self._last_peer_health_sent >= self.config.health_interval:
            self.primary_endpoint.send(
                Message(MsgType.HEALTH_UPDATE, self.name))
            self._last_peer_health_sent = now
        # an unanswered resync request is re-sent at heartbeat cadence
        # (the request itself crosses the same partitioned link)
        if self._resync_pending \
                and now - self._last_resync_req >= self.config.health_interval:
            self._request_resync(now)
        # messages from the primary
        while True:
            m = self.primary_endpoint.poll()
            if m is None:
                break
            if m.type in self._REPLICATED:
                rep = (m.body or {}).get("rep")
                if rep is not None:
                    if self._resync_pending:
                        # stale mirror: everything until SYNC_STATE is
                        # already covered by the snapshot we asked for
                        continue
                    if rep != self._expect_rep:
                        self._request_resync(now)
                        continue
                    self._expect_rep = rep + 1
            if m.type == MsgType.HEALTH_UPDATE:
                self.primary_last_health = now
            elif m.type == MsgType.SYNC_STATE:
                self._apply_sync_state(m.body["state"], now)
                self.primary_last_health = now
            elif m.type == MsgType.FORWARD:
                inner: Message = m.body["msg"]
                self._pop_direct(inner)
                self.process_client_message(inner)
            elif m.type == MsgType.BROADCAST:
                # mirror the primary's control broadcast: consume the same
                # ctrl_seq in our core and re-send on the backup channels
                # (clients dedup whichever copy arrives second)
                bbody = (m.body or {}).get("body")
                if m.body["mtype"] is MsgType.APPLY_DOMINO_EFFECT \
                        and bbody is not None:
                    # cross-shard gossip notice: absorb into the mirror's
                    # min_hard too (the state change is the point; the
                    # replication stream guarantees retained-ness agrees)
                    vals = bbody.get("hardnesses") \
                        or (bbody["hardness"],)
                    _, effects = self.core.gossip_hardness(
                        [Hardness(tuple(v)) for v in vals])
                else:
                    effects = self.core.control_broadcast(m.body["mtype"])
                for eff in effects:
                    self._apply(eff, now)
            elif m.type == MsgType.NEW_CLIENT:
                b = m.body
                ci = self.core.register_client(
                    b["name"], b["srv_seq"], b["last_client_seq"], now,
                    endpoint=self.engine.backup_endpoint(b["name"]))
                self._own_endpoint(ci)
                self._direct_buffer.setdefault(b["name"], [])
            elif m.type == MsgType.CLIENT_TERMINATED:
                self.core.forget_client(m.body["name"])
                self._disown_endpoint(m.body["name"])
                self._direct_buffer.pop(m.body["name"], None)
        self._mark_drained(self.primary_endpoint)
        # client-link partition detection -> typed core events
        self._poll_client_links(now)
        # direct copies from clients -> buffer (a client's endpoint can be
        # None when its instance was deleted while the registration flew)
        def buffer_direct(ci: ClientInfo):
            while True:
                m = ci.endpoint.poll()
                if m is None:
                    break
                if m.seq <= ci.last_client_seq:
                    continue  # processed by primary before the snapshot
                self._direct_buffer.setdefault(ci.name, []).append(m)
                if m.type == MsgType.HEALTH_UPDATE:
                    ci.last_health = now
        self._drain_ready(now, buffer_direct)
        # primary failure -> take over.  Silence across a *known*
        # partition gets partition_grace_s first: taking over while the
        # primary is alive behind a healable link would split-brain —
        # beyond the grace we must assume real death and proceed
        limit, self.primary_last_health = \
            self._peer_liveness(now, self.primary_last_health)
        if now - self.primary_last_health > limit:
            self._take_over()

    def _pop_direct(self, inner: Message):
        buf = self._direct_buffer.get(inner.sender)
        if not buf:
            return
        self._direct_buffer[inner.sender] = [
            m for m in buf if m.key() != inner.key()]

    def _take_over(self):
        """The backup becomes the primary (paper §c)."""
        self.role = "primary"
        self.name = "primary*"
        # swap queues on every client via their (old) primary channels; the
        # engine rotates the channel registry (old backup link -> primary
        # link) and mints a fresh backup link per client, shipped inside
        # SWAP_QUEUES — a later backup must not attach to the endpoint this
        # server now polls, or it would steal client messages
        rotate = getattr(self.engine, "rotate_client_channels", None)
        for cname in self.core.clients:
            ep = self.engine.primary_endpoints(cname)
            new_backup = rotate(cname) if rotate is not None else None
            if ep is not None:
                ep.send(Message(MsgType.SWAP_QUEUES, self.name,
                                {"new_backup": new_backup}))
        # force re-grant verification of every in-flight assignment: if
        # the mirror missed a RESULT (lost FORWARD, no resync before the
        # primary died) the task would otherwise stay ASSIGNED to a client
        # that already finished it.  A client still holding the task just
        # re-ACKs the grant; one that finished re-runs it (at-least-once)
        for ci in self.core.clients.values():
            for tid in ci.assigned:
                ci.unacked[tid] = -1e18
        # process buffered direct messages in order
        for cname in list(self._direct_buffer):
            if cname not in self.core.clients:
                continue
            for m in sorted(self._direct_buffer.pop(cname, []),
                            key=lambda m: m.seq):
                self.process_client_message(m)
        # dangling-instance cleanup: delete instances with no client object.
        # Backup servers are recognized by the engine's kind registry, not
        # by their name (a client named "backup…" must still be reaped).
        known = set(self.core.clients) | {self.name}
        kind_of = getattr(self.engine, "instance_kind", None)
        for iname in self.engine.list_instances():
            if iname in known:
                continue
            kind = kind_of(iname) if kind_of is not None else None
            if kind == "backup" or \
                    (kind is None and iname.startswith("backup")):
                continue   # name-prefix fallback for registry-less engines
            self.engine.terminate_instance(iname)
        self.backup_endpoint = None
        self.backup_name = None
        self.backup_pending = False
        self._resync_pending = False
        # the old primary may have died frozen (mid backup creation, after
        # STOP): release any stopped clients — clients that already
        # resumed dedup the ctrl_seq or no-op on a second RESUME
        self._broadcast(MsgType.RESUME, self.now())

    # ------------------------------------------------------------------
    def next_wake(self, now: float) -> float:
        """Earliest future time this server needs attention absent incoming
        messages: the next heartbeat tick (which also bounds how late the
        liveness checks run) or a pending instance-creation backoff expiry.
        Scheduling hint for the discrete-event simulator only."""
        nxt = now + self.config.health_interval
        if self.role == "primary" and now < self._next_create_at:
            nxt = min(nxt, self._next_create_at)
        return max(nxt, now + 1e-6)

    # ------------------------------------------------------------------
    def run(self, poll_sleep: float = 0.02, stop_when_done: bool = True):
        """Drive the loop with the engine's real clock (LocalEngine/GCE).
        The paper keeps servers alive after results are output; callers who
        want that behaviour pass stop_when_done=False and stop externally.
        """
        import time as _t

        while True:
            self.step()
            if self.done and stop_when_done:
                return self.final_results
            _t.sleep(poll_sleep)
