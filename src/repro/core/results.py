"""Results table (GROUP-BY retention, original task order) + client event
logs — the paper's "output folder" contents.
"""
from __future__ import annotations

import collections
import json
import os
from dataclasses import dataclass, field


class EventLog:
    def __init__(self):
        self._events: dict[str, list] = {}

    def ensure(self, client: str):
        self._events.setdefault(client, [])

    def log(self, client: str, t: float, kind: str, body):
        self._events.setdefault(client, []).append(
            {"t": t, "kind": kind, "body": body})

    def snapshot(self):
        return {c: list(v) for c, v in self._events.items()}

    def restore(self, snap):
        self._events = {c: list(v) for c, v in snap.items()}

    def for_client(self, client: str) -> list:
        return list(self._events.get(client, []))

    def write(self, out_dir: str):
        for client, events in self._events.items():
            d = os.path.join(out_dir, client)
            os.makedirs(d, exist_ok=True)
            with open(os.path.join(d, "events.jsonl"), "w") as f:
                for e in events:
                    f.write(json.dumps(e, default=str) + "\n")


@dataclass
class ResultsTable:
    parameter_titles: tuple
    result_titles: tuple
    rows: list                      # [(params, result, status)]
    dropped_groups: list = field(default_factory=list)
    # cost accounting (CostMeter, threaded engine -> server -> here):
    # per-row attributed cost (seconds the task ran x its instance's
    # $/instance-second rate; None for unsolved rows) + run-level summary
    row_costs: list | None = None
    cost: dict | None = None

    @classmethod
    def build(cls, tasks, original_index, status, results,
              min_group_size: int = 0, task_costs: dict | None = None,
              cost: dict | None = None) -> ResultsTable:
        if not tasks:
            return cls((), (), [], cost=cost)
        # group retention: a group is kept if #solved >= min_group_size
        solved_per_group = collections.Counter()
        for tid, task in enumerate(tasks):
            if tid in results:
                solved_per_group[task.group_key()] += 1
        dropped = set()
        if min_group_size > 0:
            for task in tasks:
                gk = task.group_key()
                if solved_per_group[gk] < min_group_size:
                    dropped.add(gk)
        # restore original order (paper: prior to printing results)
        by_original = sorted(range(len(tasks)),
                             key=lambda i: original_index[i])
        rows = []
        row_costs = [] if task_costs is not None else None
        for tid in by_original:
            task = tasks[tid]
            if min_group_size > 0 and task.group_key() in dropped:
                continue
            rows.append((task.parameters(), results.get(tid),
                         status[tid]))
            if row_costs is not None:
                row_costs.append(task_costs.get(tid))
        return cls(
            parameter_titles=tasks[0].parameter_titles(),
            result_titles=tasks[0].result_titles(),
            rows=rows,
            dropped_groups=sorted(dropped),
            row_costs=row_costs,
            cost=cost,
        )

    # ------------------------------------------------------------------
    def solved_rows(self):
        return [(p, r) for p, r, s in self.rows if r is not None]

    def to_csv(self) -> str:
        cost_col = ("cost",) if self.row_costs is not None else ()
        header = ",".join(map(str, self.parameter_titles + self.result_titles
                              + ("status",) + cost_col))
        lines = [header]
        for i, (params, result, status) in enumerate(self.rows):
            res = result if result is not None else ("",) * len(
                self.result_titles)
            cost = ()
            if self.row_costs is not None:
                c = self.row_costs[i]
                cost = (f"{c:.6g}" if c is not None else "",)
            lines.append(",".join(map(str, tuple(params) + tuple(res)
                                      + (status,) + cost)))
        return "\n".join(lines)

    def write(self, out_dir: str):
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "results.csv"), "w") as f:
            f.write(self.to_csv() + "\n")
        if self.cost is not None:
            with open(os.path.join(out_dir, "cost.json"), "w") as f:
                json.dump(self.cost, f, indent=2)
