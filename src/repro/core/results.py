"""Results table (GROUP-BY retention, original task order) + client event
logs — the paper's "output folder" contents.
"""
from __future__ import annotations

import collections
import json
import os
from dataclasses import dataclass, field


class EventLog:
    def __init__(self):
        self._events: dict[str, list] = {}

    def ensure(self, client: str):
        self._events.setdefault(client, [])

    def log(self, client: str, t: float, kind: str, body):
        self._events.setdefault(client, []).append(
            {"t": t, "kind": kind, "body": body})

    def snapshot(self):
        return {c: list(v) for c, v in self._events.items()}

    def restore(self, snap):
        self._events = {c: list(v) for c, v in snap.items()}

    def for_client(self, client: str) -> list:
        return list(self._events.get(client, []))

    def write(self, out_dir: str):
        for client, events in self._events.items():
            d = os.path.join(out_dir, client)
            os.makedirs(d, exist_ok=True)
            with open(os.path.join(d, "events.jsonl"), "w") as f:
                for e in events:
                    f.write(json.dumps(e, default=str) + "\n")


@dataclass
class ResultsTable:
    parameter_titles: tuple
    result_titles: tuple
    rows: list                      # [(params, result, status)]
    dropped_groups: list = field(default_factory=list)

    @classmethod
    def build(cls, tasks, original_index, status, results,
              min_group_size: int = 0) -> "ResultsTable":
        if not tasks:
            return cls((), (), [])
        # group retention: a group is kept if #solved >= min_group_size
        solved_per_group = collections.Counter()
        for tid, task in enumerate(tasks):
            if tid in results:
                solved_per_group[task.group_key()] += 1
        dropped = set()
        if min_group_size > 0:
            for tid, task in enumerate(tasks):
                gk = task.group_key()
                if solved_per_group[gk] < min_group_size:
                    dropped.add(gk)
        # restore original order (paper: prior to printing results)
        by_original = sorted(range(len(tasks)),
                             key=lambda i: original_index[i])
        rows = []
        for tid in by_original:
            task = tasks[tid]
            if min_group_size > 0 and task.group_key() in dropped:
                continue
            rows.append((task.parameters(), results.get(tid),
                         status[tid]))
        return cls(
            parameter_titles=tasks[0].parameter_titles(),
            result_titles=tasks[0].result_titles(),
            rows=rows,
            dropped_groups=sorted(dropped),
        )

    # ------------------------------------------------------------------
    def solved_rows(self):
        return [(p, r) for p, r, s in self.rows if r is not None]

    def to_csv(self) -> str:
        header = ",".join(map(str, self.parameter_titles + self.result_titles
                              + ("status",)))
        lines = [header]
        for params, result, status in self.rows:
            res = result if result is not None else ("",) * len(
                self.result_titles)
            lines.append(",".join(map(str, tuple(params) + tuple(res)
                                      + (status,))))
        return "\n".join(lines)

    def write(self, out_dir: str):
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "results.csv"), "w") as f:
            f.write(self.to_csv() + "\n")
