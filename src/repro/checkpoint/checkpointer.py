"""Checkpointing: atomic, async-capable, reshard-on-restore.

Layout:  <dir>/step_<N>/arrays.npz + meta.json   (tmp-dir + rename = atomic)

* ``save`` snapshots to host (jax.device_get) synchronously, then writes to
  disk either inline or on a background thread (``async_write=True``) so the
  train loop overlaps I/O with compute — the fault-tolerance story at scale
  is frequent cheap checkpoints, not rare heroic ones.
* ``restore`` takes a *like* tree (array or ShapeDtypeStruct leaves) for
  structure, and an optional shardings tree: arrays are device_put with the
  *target* sharding, which is what makes elastic restarts onto a different
  mesh work (see sharding/reshard.py and tests/test_checkpoint.py).
"""
from __future__ import annotations

import contextlib
import json
import os
import shutil
import threading
import time

import jax
import ml_dtypes
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(directory: str, step: int, tree, *, metadata: dict | None = None,
         async_write: bool = False) -> threading.Thread | None:
    """Snapshot ``tree`` for ``step``. Returns the writer thread if async."""
    host = {}
    dtypes = {}
    for k, v in _flatten(tree).items():
        arr = np.asarray(jax.device_get(v))
        dtypes[k] = str(arr.dtype)
        if arr.dtype == ml_dtypes.bfloat16:  # npz can't round-trip bf16
            arr = arr.view(np.uint16)
        host[k] = arr
    meta = dict(metadata or {}, step=step, time=time.time(), dtypes=dtypes)

    def write():
        os.makedirs(directory, exist_ok=True)
        tmp = os.path.join(directory, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            with contextlib.suppress(ValueError):
                steps.append(int(name.split("_", 1)[1]))
    return sorted(steps)


def restore(directory: str, like, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like``. Returns (tree, step, meta)."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step}")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    keys = _flatten(like)
    sh = _flatten(shardings) if shardings is not None else {}
    leaves, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = list(keys)
    out = {}
    dtype_map = meta.get("dtypes", {})
    for key, leaf in keys.items():
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if dtype_map.get(key) == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        arr = arr.astype(leaf.dtype)
        out[key] = (jax.device_put(arr, sh[key])
                    if key in sh and sh[key] is not None
                    else jax.device_put(arr))
    restored = jax.tree_util.tree_unflatten(
        treedef, [out[k] for k in flat_paths])
    return restored, step, meta


def prune(directory: str, keep: int = 3):
    steps = available_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
