"""GQA/MQA attention with qk-norm, partial/interleaved RoPE, and a decode
path against a pre-allocated KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import rmsnorm
from repro.models.params import Param
from repro.models.rope import apply_rope
from repro.sharding.rules import shard


def make_attention(cfg):
    d = cfg.d_model
    p = {
        "wq": Param((d, cfg.q_dim), ("embed", "heads"), init="scaled"),
        "wk": Param((d, cfg.kv_dim), ("embed", "kv_heads"), init="scaled"),
        "wv": Param((d, cfg.kv_dim), ("embed", "kv_heads"), init="scaled"),
        "wo": Param((cfg.q_dim, d), ("heads", "embed"), init="scaled"),
    }
    if cfg.qk_norm:
        p["q_norm"] = Param((cfg.head_dim,), (None,), init="ones")
        p["k_norm"] = Param((cfg.head_dim,), (None,), init="ones")
    return p


def _qkv(cfg, p, x, positions):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    rd = cfg.rotary_dim
    if rd:
        q = apply_rope(q, positions, theta=cfg.rope_theta, rotary_dim=rd,
                       interleaved=cfg.rope_interleaved)
        k = apply_rope(k, positions, theta=cfg.rope_theta, rotary_dim=rd,
                       interleaved=cfg.rope_interleaved)
    return q, k, v


def apply_attention(cfg, p, x, positions):
    """Full-sequence causal attention (train / prefill).

    x: [B, S, d]; positions: [S] or [B, S]. Returns ([B, S, d], (k, v))."""
    q, k, v = _qkv(cfg, p, x, positions)
    q = shard(q, "batch", "seq", None, None)
    k = shard(k, "batch", "seq_kv", None, None)
    out = ops.flash_attention(q, k, v, causal=True)
    out = out.reshape(*x.shape[:2], cfg.q_dim)
    out = shard(out, "batch", "seq", "heads")
    return out @ p["wo"], (k, v)


def make_kv_cache(cfg, batch: int, max_seq: int, stack: tuple = ()):
    """Descriptor tree for the KV cache (materialise with init_params or
    abstract_params)."""
    lead = tuple(stack)
    lead_logical = (None,) * len(lead)
    shape = (*lead, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    logical = (*lead_logical, "batch", "seq_kv", "kv_heads", None)
    return {
        "k": Param(shape, logical, init="zeros", dtype=cfg.dtype),
        "v": Param(shape, logical, init="zeros", dtype=cfg.dtype),
    }


def make_kv_cache_paged(cfg, num_pages: int, page_size: int,
                        stack: tuple = ()):
    """Descriptor tree for a *paged* KV cache: a pool of
    ``num_pages × page_size`` token rows shared by every slot, indexed
    through per-slot page tables instead of a dense ``batch × max_seq``
    stripe.  No ``batch`` axis — resident memory is decoupled from
    slots × max_seq."""
    lead = tuple(stack)
    lead_logical = (None,) * len(lead)
    shape = (*lead, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    logical = (*lead_logical, None, "seq_kv", "kv_heads", None)
    return {
        "k": Param(shape, logical, init="zeros", dtype=cfg.dtype),
        "v": Param(shape, logical, init="zeros", dtype=cfg.dtype),
    }


def _paged_rows(pool):
    """Flatten [P, ps, ...] pool to [(P*ps), ...] token rows."""
    P, ps = pool.shape[0], pool.shape[1]
    return pool.reshape(P * ps, *pool.shape[2:])


def paged_write_rows(pool, page_table, positions, values, active=None):
    """Scatter per-token rows through a page table.

    pool: [P, ps, ...]; page_table: [B, W] int32; positions: [B] or
    [B, C] int32 logical token positions; values: rows matching
    ``positions`` with trailing dims of the pool; active: optional [B]
    bool — inactive slots' writes are dropped (their stale table entries
    may point at pages now owned by other slots, so the drop is a
    correctness requirement, not an optimisation)."""
    P, ps = pool.shape[0], pool.shape[1]
    W = page_table.shape[1]
    B = page_table.shape[0]
    logical_pg = jnp.clip(positions // ps, 0, W - 1)
    if positions.ndim == 1:
        phys = page_table[jnp.arange(B), logical_pg]            # [B]
        amask = active if active is not None else None
    else:
        phys = page_table[jnp.arange(B)[:, None], logical_pg]   # [B, C]
        amask = active[:, None] if active is not None else None
    flat = phys * ps + positions % ps
    if amask is not None:
        flat = jnp.where(amask, flat, P * ps)   # out of range -> dropped
    rows = _paged_rows(pool).at[flat].set(values, mode="drop")
    return rows.reshape(pool.shape)


def apply_attention_decode_paged(cfg, p, x, cache, pos, page_table,
                                 active=None):
    """One-token decode against the paged pool.  x: [B, 1, d]; cache:
    {k,v: [P, ps, K, hd]}; pos: [B] int32; page_table: [B, W] int32
    (traced — constant within a fused sync, updated by the engine's
    allocator between syncs); active: optional [B] bool.
    Returns (out, new_cache)."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x, pos[:, None])
    k = paged_write_rows(cache["k"], page_table, pos, k_new[:, 0], active)
    v = paged_write_rows(cache["v"], page_table, pos, v_new[:, 0], active)
    out = ops.decode_attention_paged(q[:, 0], k, v, page_table, pos + 1,
                                     scale=cfg.head_dim ** -0.5)
    out = out.reshape(B, 1, cfg.q_dim)
    return out @ p["wo"], {"k": k, "v": v}


def apply_attention_prefill_chunk_paged(cfg, p, x, cache, start, page_table,
                                        active=None):
    """Batched C-token prefill through the page table.  Same contract as
    ``apply_attention_prefill_chunk`` with the dense stripe replaced by
    the pool: KV rows scatter to ``table[b, pos//ps]*ps + pos%ps`` and
    the chunk attends to the slot's gathered pages under the usual
    kpos <= start+q mask (stale rows of unwritten pages sit beyond the
    mask).  Returns (out [B, C, d], new_cache)."""
    from repro.kernels.ref import gather_pages

    B, C, _ = x.shape
    positions = start[:, None] + jnp.arange(C)[None, :]         # [B, C]
    q, k_new, v_new = _qkv(cfg, p, x, positions)
    k = paged_write_rows(cache["k"], page_table, positions, k_new, active)
    v = paged_write_rows(cache["v"], page_table, positions, v_new, active)
    kg = gather_pages(k, page_table)                   # [B, W*ps, K, hd]
    vg = gather_pages(v, page_table)
    smax = kg.shape[1]
    K = kg.shape[2]
    G = cfg.num_heads // K
    qg = q.reshape(B, C, K, G, cfg.head_dim).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kg.astype(jnp.float32))
    scores = scores * (cfg.head_dim ** -0.5)
    mask = jnp.arange(smax)[None, None, :] <= positions[:, :, None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vg.astype(jnp.float32))
    out = out.reshape(B, C, cfg.q_dim).astype(x.dtype)
    return out @ p["wo"], {"k": k, "v": v}


def apply_attention_prefill_chunk(cfg, p, x, cache, start, active=None):
    """Batched prefill of a C-token chunk into the KV cache.

    x: [B, C, d]; cache: {k,v: [B, Smax, K, hd]}; start: [B] int32 (cache
    position of the chunk's first token — per-slot, so freshly admitted
    requests prefill while resident slots sit at different fill levels);
    active: optional [B] bool — inactive slots leave the cache untouched
    and their outputs are garbage (callers must ignore them).

    This is ``flash_attention(q_offset=...)`` generalised to a *traced
    per-slot* offset vector: chunk queries attend to the full cache with a
    kpos <= start+q mask.  Returns (out [B, C, d], new_cache)."""
    B, C, _ = x.shape
    positions = start[:, None] + jnp.arange(C)[None, :]         # [B, C]
    q, k_new, v_new = _qkv(cfg, p, x, positions)
    smax = cache["k"].shape[1]
    wpos = positions if active is None else jnp.where(
        active[:, None], positions, smax)
    b_idx = jnp.arange(B)[:, None]
    k = cache["k"].at[b_idx, wpos, ...].set(k_new, mode="drop")
    v = cache["v"].at[b_idx, wpos, ...].set(v_new, mode="drop")
    K = k.shape[2]
    G = cfg.num_heads // K
    qg = q.reshape(B, C, K, G, cfg.head_dim).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores * (cfg.head_dim ** -0.5)
    mask = jnp.arange(smax)[None, None, :] <= positions[:, :, None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    out = out.reshape(B, C, cfg.q_dim).astype(x.dtype)
    return out @ p["wo"], {"k": k, "v": v}


def apply_attention_decode(cfg, p, x, cache, pos, active=None):
    """One-token decode. x: [B, 1, d]; cache: {k,v: [B, Smax, K, hd]};
    pos: [B] int32 (index of the new token); active: optional [B] bool —
    inactive slots leave the cache untouched (continuous batching).
    Returns (out, new_cache)."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x, pos[:, None])
    b_idx = jnp.arange(B)
    smax = cache["k"].shape[1]
    wpos = pos if active is None else jnp.where(active, pos, smax)
    k = cache["k"].at[b_idx, wpos, ...].set(k_new[:, 0], mode="drop")
    v = cache["v"].at[b_idx, wpos, ...].set(v_new[:, 0], mode="drop")
    # position p attended iff p <= pos, i.e. p < pos + 1 == kv_len.  The
    # dispatcher's ref path is bit-identical to the previous inline einsum
    # formulation; on TPU / REPRO_PALLAS=interpret the Sq=1 Pallas decode
    # kernel skips the dead cache tail per slot.
    out = ops.decode_attention(q[:, 0], k, v, pos + 1,
                               scale=cfg.head_dim ** -0.5)
    out = out.reshape(B, 1, cfg.q_dim)
    return out @ p["wo"], {"k": k, "v": v}
