"""Rotary position embeddings: full (llama), partial/interleaved (GLM 2d)."""
from __future__ import annotations

import jax.numpy as jnp


def _angles(positions, rotary_dim: int, theta: float):
    """positions [...,S] -> [..., S, rotary_dim//2] angles (fp32)."""
    half = rotary_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x, positions, *, theta: float = 10000.0,
               rotary_dim: int | None = None, interleaved: bool = False):
    """x: [B, S, H, D] (or [B, S, D] treated as H=1), positions: [S] or [B, S].

    interleaved=True pairs (0,1),(2,3),... (GLM/chatglm 2d-RoPE);
    False uses the llama half-split convention.
    Only the first ``rotary_dim`` features rotate; the rest pass through.
    """
    D = x.shape[-1]
    rotary_dim = D if rotary_dim is None else rotary_dim
    if rotary_dim == 0:
        return x
    ang = _angles(positions, rotary_dim, theta)  # [..., S, half]
    # broadcast to [B, S, 1, half] against x [B, S, H, D]
    while ang.ndim < x.ndim:
        ang = ang[..., None, :] if ang.ndim == x.ndim - 1 else ang[None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :rotary_dim].astype(jnp.float32), x[..., rotary_dim:]
    if interleaved:
        x1, x2 = xr[..., 0::2], xr[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        rot = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    else:
        half = rotary_dim // 2
        x1, x2 = xr[..., :half], xr[..., half:]
        rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1) if rotary_dim < D \
        else rot.astype(x.dtype)
