"""Parameter descriptors: single source of truth for shape, init and
sharding of every model parameter.

Model definitions build a nested-dict tree of ``Param`` leaves; the tree is
then materialised three ways:

* ``init_params(tree, rng)``        -> tree of arrays (real init)
* ``abstract_params(tree)``         -> tree of ShapeDtypeStruct (dry-run)
* ``param_specs(tree, rules)``      -> tree of PartitionSpec
* ``param_shardings(tree, rules)``  -> tree of NamedSharding

so the dry-run never allocates and the real path shares the same metadata.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Param:
    shape: tuple
    logical: tuple          # logical axis name (or None) per dim
    init: str = "normal"    # normal | zeros | ones | scaled | const
    dtype: str = "bfloat16"
    scale: float | None = None  # for 'normal': std; for 'const': the value

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_param(x) -> bool:
    return isinstance(x, Param)


def tree_map(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_param)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _init_one(p: Param, key) -> jax.Array:
    dtype = jnp.dtype(p.dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "const":
        return jnp.full(p.shape, p.scale, dtype)
    if p.init == "scaled":  # 1/sqrt(fan_in) for matmul weights
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)
    std = 0.02 if p.scale is None else p.scale
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def init_params(tree, rng):
    """Deterministic init: each leaf's key is rng folded with its path hash."""

    def go(path, p: Param):
        # zlib.crc32 is stable across processes (hash() is salted)
        h = np.uint32(zlib.crc32(_path_str(path).encode()))
        return _init_one(p, jax.random.fold_in(rng, h))

    return jax.tree_util.tree_map_with_path(go, tree, is_leaf=is_param)


def abstract_params(tree, rules=None):
    def go(p: Param):
        sharding = rules.sharding(p.logical, p.shape) if rules is not None else None
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype), sharding=sharding)

    return tree_map(go, tree)


def param_specs(tree, rules):
    return tree_map(lambda p: rules.spec(p.logical, p.shape), tree)


def param_shardings(tree, rules):
    return tree_map(lambda p: rules.sharding(p.logical, p.shape), tree)


def param_count(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_param)
    return sum(int(np.prod(p.shape)) for p in leaves)


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)
