"""Mamba-2 block (SSD formulation) with chunked-scan train/prefill and
O(1)-state decode.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import rmsnorm
from repro.models.params import Param
from repro.sharding.rules import shard


def _dims(cfg):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nheads = s.n_heads(cfg.d_model)
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nheads, conv_ch


def make_mamba(cfg):
    s, d_in, nheads, conv_ch = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nheads
    return {
        "in_proj": Param((d, proj_out), ("embed", "ffn"), init="scaled"),
        "conv_w": Param((s.d_conv, conv_ch), (None, "ffn"), init="scaled"),
        "conv_b": Param((conv_ch,), ("ffn",), init="zeros"),
        "A_log": Param((nheads,), (None,), init="const", scale=0.5,
                       dtype="float32"),
        "D": Param((nheads,), (None,), init="ones", dtype="float32"),
        "dt_bias": Param((nheads,), (None,), init="zeros", dtype="float32"),
        "norm": Param((d_in,), (None,), init="ones"),
        "out_proj": Param((d_in, d), ("ffn", "embed"), init="scaled"),
    }


def _split_proj(cfg, proj):
    s, d_in, nheads, _ = _dims(cfg)
    gs = s.n_groups * s.d_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in: 2 * d_in + 2 * gs]
    dt = proj[..., 2 * d_in + 2 * gs:]
    return z, xbc, dt


def _causal_conv(p, xbc):
    """Depthwise causal conv via K shifted adds (K=d_conv is tiny)."""
    K = p["conv_w"].shape[0]
    out = xbc * p["conv_w"][K - 1]
    for i in range(1, K):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * p["conv_w"][K - 1 - i]
    return jax.nn.silu(out + p["conv_b"])


def apply_mamba(cfg, p, x, positions=None):
    """Full-sequence Mamba-2 (train/prefill).

    x: [B, S, d] -> (y [B, S, d], (conv_state, ssm_state)) where
    conv_state: [B, d_conv-1, conv_ch], ssm_state: [B, H, P, N] fp32."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    B, S, _ = x.shape
    proj = x @ p["in_proj"]
    proj = shard(proj, "batch", "seq", "ffn")
    z, xbc, dt_raw = _split_proj(cfg, proj)
    conv_state = xbc[:, S - (s.d_conv - 1):, :]  # final (d_conv-1) inputs
    xbc = _causal_conv(p, xbc)
    xs = xbc[..., :d_in].reshape(B, S, nheads, s.head_dim)
    Bm = xbc[..., d_in: d_in + s.n_groups * s.d_state].reshape(
        B, S, s.n_groups, s.d_state)
    Cm = xbc[..., d_in + s.n_groups * s.d_state:].reshape(
        B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    chunk = int(os.environ.get("REPRO_SSD_CHUNK", s.chunk))
    y, h_final = ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=chunk,
                              return_final_state=True)
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    y = shard(y, "batch", "seq", "ffn")
    return y @ p["out_proj"], (conv_state, h_final)


def make_mamba_cache(cfg, batch: int, stack: tuple = ()):
    s, d_in, nheads, conv_ch = _dims(cfg)
    lead = tuple(stack)
    ll = (None,) * len(lead)
    return {
        "conv": Param((*lead, batch, s.d_conv - 1, conv_ch),
                      (*ll, "batch", None, "ffn"), init="zeros",
                      dtype=cfg.dtype),
        "ssm": Param((*lead, batch, nheads, s.head_dim, s.d_state),
                     (*ll, "batch", None, None, None), init="zeros",
                     dtype="float32"),
    }


def apply_mamba_prefill_chunk(cfg, p, x, cache, start=None, active=None):
    """Prefill a C-token chunk, carrying conv + SSM state across chunks.

    x: [B, C, d]; cache {conv: [B, d_conv-1, ch], ssm: [B, H, P, N]};
    start is unused (SSM state is position-free) but kept for signature
    parity with the attention variants; active: optional [B] bool —
    inactive slots keep their state unchanged, outputs garbage.

    The conv left-context comes from the cached last (d_conv-1) raw xbc
    inputs, so chunked prefill matches full-sequence ``apply_mamba`` up to
    the chunked-vs-sequential SSD fp tolerance.  Returns (out, new_cache)."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    B, C, _ = x.shape
    proj = x @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)
    K = s.d_conv
    window = jnp.concatenate(
        [cache["conv"].astype(xbc_raw.dtype), xbc_raw], axis=1)  # [B,K-1+C,ch]
    new_conv = window[:, -(K - 1):].astype(cache["conv"].dtype)
    # conv over the window: positions >= K-1 see only real left context
    xbc = _causal_conv(p, window)[:, K - 1:]                     # [B, C, ch]
    xs = xbc[..., :d_in].reshape(B, C, nheads, s.head_dim)
    Bm = xbc[..., d_in: d_in + s.n_groups * s.d_state].reshape(
        B, C, s.n_groups, s.d_state)
    Cm = xbc[..., d_in + s.n_groups * s.d_state:].reshape(
        B, C, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_final = ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=min(s.chunk, C),
                              h0=cache["ssm"], return_final_state=True)
    if active is not None:
        h_final = jnp.where(active.reshape(B, 1, 1, 1), h_final, cache["ssm"])
        new_conv = jnp.where(active.reshape(B, 1, 1), new_conv, cache["conv"])
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, C, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"]).astype(x.dtype)
    return out, {"conv": new_conv, "ssm": h_final}


def apply_mamba_decode(cfg, p, x, cache, pos=None, active=None):
    """One-token decode. x: [B, 1, d]; cache {conv, ssm}; active: optional
    [B] bool — inactive slots keep their conv/SSM state unchanged."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    B = x.shape[0]
    proj = x[:, 0] @ p["in_proj"]  # [B, proj_out]
    z, xbc_new, dt_raw = _split_proj(cfg, proj)
    conv = cache["conv"]  # [B, K-1, conv_ch]
    K = s.d_conv
    window = jnp.concatenate([conv, xbc_new[:, None, :]], axis=1)  # [B,K,ch]
    xbc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv = window[:, 1:]
    xs = xbc[..., :d_in].reshape(B, nheads, s.head_dim)
    Bm = xbc[..., d_in: d_in + s.n_groups * s.d_state].reshape(
        B, s.n_groups, s.d_state)
    Cm = xbc[..., d_in + s.n_groups * s.d_state:].reshape(
        B, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    from repro.kernels.ref import ssd_decode_step_ref

    y, h_new = ssd_decode_step_ref(xs, dt, A, Bm, Cm, cache["ssm"])
    if active is not None:
        gate = active.reshape(B, 1, 1, 1)
        h_new = jnp.where(gate, h_new, cache["ssm"])
        new_conv = jnp.where(active.reshape(B, 1, 1), new_conv, cache["conv"])
    y = y + xs * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B, 1, d_in)
    y = rmsnorm(y * jax.nn.silu(z[:, None, :]), p["norm"], cfg.norm_eps)
    # keep the residual-stream dtype even when the conv cache is fp32
    out = (y @ p["out_proj"]).astype(x.dtype)
    return out, {"conv": new_conv, "ssm": h_new}
