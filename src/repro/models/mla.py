"""Multi-head Latent Attention (DeepSeek-V2/V3).

Prefill/train: materialise per-head K/V from the compressed latent.
Decode: *weight-absorbed* path — queries are projected into the latent space
so attention runs directly against the cached (c_kv, k_pe); the cache is
(kv_lora_rank + qk_rope_head_dim) per token instead of 2·H·head_dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import rmsnorm
from repro.models.params import Param
from repro.models.rope import apply_rope
from repro.sharding.rules import shard


def make_mla(cfg):
    d, m, H = cfg.d_model, cfg.mla, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": Param((d, m.q_lora_rank), ("embed", "q_lora"), init="scaled"),
        "q_norm": Param((m.q_lora_rank,), (None,), init="ones"),
        "wuq": Param((m.q_lora_rank, H * qk_head), ("q_lora", "heads"),
                     init="scaled"),
        "wdkv": Param((d, m.kv_lora_rank), ("embed", "kv_lora"), init="scaled"),
        "wkr": Param((d, m.qk_rope_head_dim), ("embed", None), init="scaled"),
        "kv_norm": Param((m.kv_lora_rank,), (None,), init="ones"),
        "wuk": Param((m.kv_lora_rank, H * m.qk_nope_head_dim),
                     ("kv_lora", "heads"), init="scaled"),
        "wuv": Param((m.kv_lora_rank, H * m.v_head_dim),
                     ("kv_lora", "heads"), init="scaled"),
        "wo": Param((H * m.v_head_dim, d), ("heads", "embed"), init="scaled"),
    }


def _queries(cfg, p, x, positions):
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.num_heads
    cq = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, theta=cfg.rope_theta)
    return q_nope, q_pe


def _latent_kv(cfg, p, x, positions):
    m = cfg.mla
    ckv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)  # [B,S,r]
    k_pe = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                      theta=cfg.rope_theta)[:, :, 0]  # [B,S,rope]
    return ckv, k_pe


def apply_mla(cfg, p, x, positions):
    """Full-sequence MLA (train/prefill). Returns (out, (ckv, k_pe))."""
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.num_heads
    q_nope, q_pe = _queries(cfg, p, x, positions)
    ckv, k_pe = _latent_kv(cfg, p, x, positions)
    k_nope = (ckv @ p["wuk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (ckv @ p["wuv"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None], (*k_pe.shape[:2], H, m.qk_rope_head_dim))],
        axis=-1,
    )
    q = shard(q, "batch", "seq", None, None)
    k = shard(k, "batch", "seq_kv", None, None)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = ops.flash_attention(q, k, v, causal=True, scale=scale)
    out = out.reshape(B, S, H * m.v_head_dim)
    out = shard(out, "batch", "seq", "heads")
    return out @ p["wo"], (ckv, k_pe)


def make_mla_cache(cfg, batch: int, max_seq: int, stack: tuple = ()):
    m = cfg.mla
    lead = tuple(stack)
    ll = (None,) * len(lead)
    return {
        "ckv": Param((*lead, batch, max_seq, m.kv_lora_rank),
                     (*ll, "batch", "seq_kv", None), init="zeros",
                     dtype=cfg.dtype),
        "kpe": Param((*lead, batch, max_seq, m.qk_rope_head_dim),
                     (*ll, "batch", "seq_kv", None), init="zeros",
                     dtype=cfg.dtype),
    }


def make_mla_cache_paged(cfg, num_pages: int, page_size: int,
                         stack: tuple = ()):
    """Paged latent cache: (ckv, kpe) pools of ``num_pages × page_size``
    rows shared by every slot through per-slot page tables."""
    m = cfg.mla
    lead = tuple(stack)
    ll = (None,) * len(lead)
    return {
        "ckv": Param((*lead, num_pages, page_size, m.kv_lora_rank),
                     (*ll, None, "seq_kv", None), init="zeros",
                     dtype=cfg.dtype),
        "kpe": Param((*lead, num_pages, page_size, m.qk_rope_head_dim),
                     (*ll, None, "seq_kv", None), init="zeros",
                     dtype=cfg.dtype),
    }


def apply_mla_prefill_chunk_paged(cfg, p, x, cache, start, page_table,
                                  active=None):
    """Weight-absorbed chunk prefill into the paged latent pools.  Same
    contract as ``apply_mla_prefill_chunk`` with the dense stripe
    replaced by page-table scatter + gather (stale rows sit beyond the
    causal mask)."""
    from repro.kernels.ref import gather_pages
    from repro.models.attention import paged_write_rows

    B, C, _ = x.shape
    m, H = cfg.mla, cfg.num_heads
    positions = start[:, None] + jnp.arange(C)[None, :]          # [B, C]
    q_nope, q_pe = _queries(cfg, p, x, positions)                # [B,C,H,*]
    ckv_new, kpe_new = _latent_kv(cfg, p, x, positions)
    ckv = paged_write_rows(cache["ckv"], page_table, positions, ckv_new,
                           active)
    kpe = paged_write_rows(cache["kpe"], page_table, positions, kpe_new,
                           active)
    ckv_g = gather_pages(ckv, page_table)                 # [B, W*ps, r]
    kpe_g = gather_pages(kpe, page_table)
    smax = ckv_g.shape[1]
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scores = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_g.astype(jnp.float32))
    scores += jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                         kpe_g.astype(jnp.float32))
    scores *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    mask = jnp.arange(smax)[None, None, :] <= positions[:, :, None]
    scores = jnp.where(mask[:, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ckv_g.astype(jnp.float32))
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wuv.astype(jnp.float32))
    out = out.reshape(B, C, H * m.v_head_dim).astype(x.dtype)
    return out @ p["wo"], {"ckv": ckv, "kpe": kpe}


def apply_mla_decode_paged(cfg, p, x, cache, pos, page_table, active=None):
    """Weight-absorbed one-token decode against the paged latent pools.
    x: [B,1,d]; cache {ckv: [P,ps,r], kpe: [P,ps,rope]}; pos: [B];
    page_table: [B,W] int32; active: optional [B] bool."""
    from repro.kernels.ref import gather_pages
    from repro.models.attention import paged_write_rows

    B = x.shape[0]
    m, H = cfg.mla, cfg.num_heads
    q_nope, q_pe = _queries(cfg, p, x, pos[:, None])  # [B,1,H,*]
    ckv_new, kpe_new = _latent_kv(cfg, p, x, pos[:, None])
    ckv = paged_write_rows(cache["ckv"], page_table, pos, ckv_new[:, 0],
                           active)
    kpe = paged_write_rows(cache["kpe"], page_table, pos, kpe_new[:, 0],
                           active)
    ckv_g = gather_pages(ckv, page_table)                 # [B, W*ps, r]
    kpe_g = gather_pages(kpe, page_table)
    smax = ckv_g.shape[1]
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, ckv_g.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_pe[:, 0].astype(jnp.float32),
                         kpe_g.astype(jnp.float32))
    scores *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    mask = jnp.arange(smax)[None, :] <= pos[:, None]
    scores = jnp.where(mask[:, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv_g.astype(jnp.float32))
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))
    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return out @ p["wo"], {"ckv": ckv, "kpe": kpe}


def apply_mla_prefill_chunk(cfg, p, x, cache, start, active=None):
    """Weight-absorbed prefill of a C-token chunk into the latent cache.

    x: [B, C, d]; cache {ckv: [B,S,r], kpe: [B,S,rope]}; start: [B] int32
    (per-slot cache position of the chunk's first token); active: optional
    [B] bool — inactive slots leave the cache untouched, outputs garbage.
    Returns (out [B, C, d], new_cache)."""
    B, C, _ = x.shape
    m, H = cfg.mla, cfg.num_heads
    positions = start[:, None] + jnp.arange(C)[None, :]          # [B, C]
    q_nope, q_pe = _queries(cfg, p, x, positions)                # [B,C,H,*]
    ckv_new, kpe_new = _latent_kv(cfg, p, x, positions)
    smax = cache["ckv"].shape[1]
    wpos = positions if active is None else jnp.where(
        active[:, None], positions, smax)
    b_idx = jnp.arange(B)[:, None]
    ckv = cache["ckv"].at[b_idx, wpos, ...].set(ckv_new, mode="drop")
    kpe = cache["kpe"].at[b_idx, wpos, ...].set(kpe_new, mode="drop")
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scores = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv.astype(jnp.float32))
    scores += jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                         kpe.astype(jnp.float32))
    scores *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    mask = jnp.arange(smax)[None, None, :] <= positions[:, :, None]
    scores = jnp.where(mask[:, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ckv.astype(jnp.float32))
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wuv.astype(jnp.float32))
    out = out.reshape(B, C, H * m.v_head_dim).astype(x.dtype)
    return out @ p["wo"], {"ckv": ckv, "kpe": kpe}


def apply_mla_decode(cfg, p, x, cache, pos, active=None):
    """Weight-absorbed one-token decode.

    x: [B,1,d]; cache {ckv: [B,S,r], kpe: [B,S,rope]}; pos: [B];
    active: optional [B] bool (inactive slots leave the cache untouched)."""
    B = x.shape[0]
    m, H = cfg.mla, cfg.num_heads
    q_nope, q_pe = _queries(cfg, p, x, pos[:, None])  # [B,1,H,*]
    ckv_new, kpe_new = _latent_kv(cfg, p, x, pos[:, None])
    b_idx = jnp.arange(B)
    smax = cache["ckv"].shape[1]
    wpos = pos if active is None else jnp.where(active, pos, smax)
    ckv = cache["ckv"].at[b_idx, wpos, ...].set(ckv_new[:, 0], mode="drop")
    kpe = cache["kpe"].at[b_idx, wpos, ...].set(kpe_new[:, 0], mode="drop")
    Smax = ckv.shape[1]
    # absorb W_UK into q: q_lat[b,h,r] = sum_d q_nope[b,h,d] W_UK[r, h*d]
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, ckv.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_pe[:, 0].astype(jnp.float32),
                         kpe.astype(jnp.float32))
    scores *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    mask = jnp.arange(Smax)[None, :] <= pos[:, None]
    scores = jnp.where(mask[:, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(jnp.float32))
    # absorb W_UV on the way out
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))
    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return out @ p["wo"], {"ckv": ckv, "kpe": kpe}
