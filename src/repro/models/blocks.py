"""Residual blocks: (mixer ∈ {attn, mla, mamba}) + (ffn ∈ {dense, moe, none}),
plus the Jamba super-block (hybrid interleave) and stacking helpers for
jax.lax.scan over layer stacks.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models.layers import (apply_dense_ffn, make_dense_ffn, make_norm,
                                 rmsnorm)
from repro.models.moe import apply_moe, make_moe
from repro.models.params import Param, tree_map


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------
def make_block(cfg, mixer: str, ffn: str):
    p = {"ln1": make_norm(cfg.d_model)}
    if mixer == "attn":
        p["mixer"] = attn_mod.make_attention(cfg)
    elif mixer == "mla":
        p["mixer"] = mla_mod.make_mla(cfg)
    elif mixer == "mamba":
        p["mixer"] = mamba_mod.make_mamba(cfg)
    else:
        raise ValueError(mixer)
    if ffn == "dense":
        p["ln2"] = make_norm(cfg.d_model)
        p["ffn"] = make_dense_ffn(cfg, cfg.d_ff_dense or cfg.d_ff)
    elif ffn == "moe":
        p["ln2"] = make_norm(cfg.d_model)
        p["ffn"] = make_moe(cfg)
    elif ffn != "none":
        raise ValueError(ffn)
    return p


def apply_block(cfg, p, h, positions, mixer: str, ffn: str):
    """Full-sequence residual block. Returns (h, aux_loss)."""
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        r, _ = attn_mod.apply_attention(cfg, p["mixer"], x, positions)
    elif mixer == "mla":
        r, _ = mla_mod.apply_mla(cfg, p["mixer"], x, positions)
    else:
        r, _ = mamba_mod.apply_mamba(cfg, p["mixer"], x, positions)
    h = h + r
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            B, S, d = x.shape
            y, aux = apply_moe(cfg, p["ffn"], x.reshape(B * S, d))
            y = y.reshape(B, S, d)
        else:
            y = apply_dense_ffn(cfg, p["ffn"], x)
        h = h + y
    return h, aux


def apply_block_collect(cfg, p, h, positions, mixer: str, ffn: str):
    """Like apply_block but also returns the prefill cache
    (attn: {k,v}, mla: {ckv,kpe}, mamba: {conv,ssm})."""
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        r, (k, v) = attn_mod.apply_attention(cfg, p["mixer"], x, positions)
        cache = {"k": k, "v": v}
    elif mixer == "mla":
        r, (ckv, kpe) = mla_mod.apply_mla(cfg, p["mixer"], x, positions)
        cache = {"ckv": ckv, "kpe": kpe}
    else:
        r, (conv, ssm) = mamba_mod.apply_mamba(cfg, p["mixer"], x, positions)
        cache = {"conv": conv, "ssm": ssm}
    h = h + r
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            Bs, S, d = x.shape
            y, aux = apply_moe(cfg, p["ffn"], x.reshape(Bs * S, d))
            y = y.reshape(Bs, S, d)
        else:
            y = apply_dense_ffn(cfg, p["ffn"], x)
        h = h + y
    return h, aux, cache


def make_block_cache(cfg, mixer: str, batch: int, max_seq: int,
                     stack: tuple = ()):
    if mixer == "attn":
        return attn_mod.make_kv_cache(cfg, batch, max_seq, stack)
    if mixer == "mla":
        return mla_mod.make_mla_cache(cfg, batch, max_seq, stack)
    return mamba_mod.make_mamba_cache(cfg, batch, stack)


def make_block_cache_paged(cfg, mixer: str, batch: int, num_pages: int,
                           page_size: int, stack: tuple = ()):
    """Paged-layout block cache: attention/MLA KV rides the shared page
    pool; mamba/SSM slots keep their O(1) dense per-slot state (it has no
    sequence axis to page)."""
    if mixer == "attn":
        return attn_mod.make_kv_cache_paged(cfg, num_pages, page_size, stack)
    if mixer == "mla":
        return mla_mod.make_mla_cache_paged(cfg, num_pages, page_size, stack)
    return mamba_mod.make_mamba_cache(cfg, batch, stack)


def apply_block_decode(cfg, p, h, cache, pos, mixer: str, ffn: str,
                       active=None, page_table=None):
    """One-token decode. ``page_table`` not None selects the paged cache
    layout for attention/MLA mixers (mamba state is dense either way).
    Returns (h, new_cache)."""
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        r, new_cache = (attn_mod.apply_attention_decode_paged(
                            cfg, p["mixer"], x, cache, pos, page_table,
                            active)
                        if page_table is not None
                        else attn_mod.apply_attention_decode(
                            cfg, p["mixer"], x, cache, pos, active))
    elif mixer == "mla":
        r, new_cache = (mla_mod.apply_mla_decode_paged(
                            cfg, p["mixer"], x, cache, pos, page_table,
                            active)
                        if page_table is not None
                        else mla_mod.apply_mla_decode(cfg, p["mixer"], x,
                                                      cache, pos, active))
    else:
        r, new_cache = mamba_mod.apply_mamba_decode(cfg, p["mixer"], x, cache,
                                                    pos, active)
    h = h + r
    if ffn != "none":
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            B, S, d = x.shape
            y, _ = apply_moe(cfg, p["ffn"], x.reshape(B * S, d))
            y = y.reshape(B, S, d)
        else:
            y = apply_dense_ffn(cfg, p["ffn"], x)
        h = h + y
    return h, new_cache


def apply_block_prefill_chunk(cfg, p, h, cache, start, mixer: str, ffn: str,
                              active=None, page_table=None):
    """Chunked prefill through one block. h: [B, C, d]; start: [B] int32
    per-slot cache offset of the chunk; ``page_table`` not None selects
    the paged layout for attention/MLA. Returns (h, new_cache)."""
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        r, new_cache = (attn_mod.apply_attention_prefill_chunk_paged(
                            cfg, p["mixer"], x, cache, start, page_table,
                            active)
                        if page_table is not None
                        else attn_mod.apply_attention_prefill_chunk(
                            cfg, p["mixer"], x, cache, start, active))
    elif mixer == "mla":
        r, new_cache = (mla_mod.apply_mla_prefill_chunk_paged(
                            cfg, p["mixer"], x, cache, start, page_table,
                            active)
                        if page_table is not None
                        else mla_mod.apply_mla_prefill_chunk(
                            cfg, p["mixer"], x, cache, start, active))
    else:
        r, new_cache = mamba_mod.apply_mamba_prefill_chunk(
            cfg, p["mixer"], x, cache, start, active)
    h = h + r
    if ffn != "none":
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            B, S, d = x.shape
            y, _ = apply_moe(cfg, p["ffn"], x.reshape(B * S, d))
            y = y.reshape(B, S, d)
        else:
            y = apply_dense_ffn(cfg, p["ffn"], x)
        h = h + y
    return h, new_cache


# ---------------------------------------------------------------------------
# stacking (scan over homogeneous layers)
# ---------------------------------------------------------------------------
def stack_descr(tree, n: int):
    """Prepend a stacked 'layers' dim of size n to every Param descriptor."""
    return tree_map(
        lambda p: Param((n, *p.shape), ("layers", *p.logical), p.init,
                        p.dtype, p.scale),
        tree,
    )


def take_layer(tree, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


# ---------------------------------------------------------------------------
# Jamba super-block
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HybridPlan:
    """Layer plan within one super-block: (group, index_within_group,
    mixer, ffn) per in-block position."""
    entries: tuple  # of (group, idx, mixer, ffn)
    group_sizes: dict

    @staticmethod
    def build(cfg) -> HybridPlan:
        hb = cfg.hybrid_block
        assert hb and cfg.num_layers % hb == 0
        m = cfg.moe
        if m is not None:
            assert hb % m.every == 0, "MoE period must divide the super-block"
        entries, sizes = [], {}
        for i in range(hb):
            mixer = "attn" if i == cfg.hybrid_attn_index else "mamba"
            ffn = "moe" if (cfg.moe is not None and cfg.is_moe_layer(i)) \
                else "dense"
            group = f"{mixer}_{ffn}"
            idx = sizes.get(group, 0)
            sizes[group] = idx + 1
            entries.append((group, idx, mixer, ffn))
        return HybridPlan(tuple(entries), sizes)


def make_super_block(cfg, plan: HybridPlan):
    p = {}
    for group, n in plan.group_sizes.items():
        mixer, ffn = group.split("_")
        p[group] = stack_descr(make_block(cfg, mixer, ffn), n)
    return p


def apply_super_block(cfg, p, h, positions, plan: HybridPlan):
    aux = jnp.zeros((), jnp.float32)
    for group, idx, mixer, ffn in plan.entries:
        h, a = apply_block(cfg, take_layer(p[group], idx), h, positions,
                           mixer, ffn)
        aux = aux + a
    return h, aux


def apply_super_block_collect(cfg, p, h, positions, plan: HybridPlan):
    aux = jnp.zeros((), jnp.float32)
    per_group = {g: [None] * n for g, n in plan.group_sizes.items()}
    for group, idx, mixer, ffn in plan.entries:
        h, a, cache = apply_block_collect(
            cfg, take_layer(p[group], idx), h, positions, mixer, ffn)
        aux = aux + a
        per_group[group][idx] = cache
    stacked = {
        g: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *lst)
        for g, lst in per_group.items()
    }
    return h, aux, stacked


def make_super_block_cache(cfg, plan: HybridPlan, batch: int, max_seq: int,
                           stack: tuple = ()):
    c = {}
    for group, n in plan.group_sizes.items():
        mixer, _ = group.split("_")
        c[group] = make_block_cache(cfg, mixer, batch, max_seq,
                                    stack=(*stack, n))
    return c


def make_super_block_cache_paged(cfg, plan: HybridPlan, batch: int,
                                 num_pages: int, page_size: int,
                                 stack: tuple = ()):
    c = {}
    for group, n in plan.group_sizes.items():
        mixer, _ = group.split("_")
        c[group] = make_block_cache_paged(cfg, mixer, batch, num_pages,
                                          page_size, stack=(*stack, n))
    return c


def apply_super_block_prefill_chunk(cfg, p, h, cache, start,
                                    plan: HybridPlan, active=None,
                                    page_table=None):
    new_cache = {g: [None] * n for g, n in plan.group_sizes.items()}
    for group, idx, mixer, ffn in plan.entries:
        h, nc = apply_block_prefill_chunk(
            cfg, take_layer(p[group], idx), h, take_layer(cache[group], idx),
            start, mixer, ffn, active, page_table)
        new_cache[group][idx] = nc
    stacked = {}
    for g, lst in new_cache.items():
        stacked[g] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *lst)
    return h, stacked


def apply_super_block_decode(cfg, p, h, cache, pos, plan: HybridPlan,
                             active=None, page_table=None):
    new_cache = {g: [None] * n for g, n in plan.group_sizes.items()}
    for group, idx, mixer, ffn in plan.entries:
        h, nc = apply_block_decode(
            cfg, take_layer(p[group], idx), h, take_layer(cache[group], idx),
            pos, mixer, ffn, active, page_table)
        new_cache[group][idx] = nc
    # restack each group's caches along the leading dim
    stacked = {}
    for g, lst in new_cache.items():
        stacked[g] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *lst)
    return h, stacked
