"""Shared layers: RMSNorm, dense FFN (SwiGLU / GELU-MLP), embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Param
from repro.sharding.rules import shard


def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def make_norm(d: int) -> Param:
    return Param((d,), (None,), init="ones")


def make_dense_ffn(cfg, width: int):
    d = cfg.d_model
    if cfg.act == "silu":  # gated SwiGLU
        return {
            "wi": Param((d, width), ("embed", "ffn"), init="scaled"),
            "wg": Param((d, width), ("embed", "ffn"), init="scaled"),
            "wo": Param((width, d), ("ffn", "embed"), init="scaled"),
        }
    return {  # classic 2-matrix GELU MLP (granite / musicgen)
        "wi": Param((d, width), ("embed", "ffn"), init="scaled"),
        "wo": Param((width, d), ("ffn", "embed"), init="scaled"),
    }


def apply_dense_ffn(cfg, p, x):
    h = x @ p["wi"]
    h = jax.nn.silu(x @ p["wg"]) * h if "wg" in p else jax.nn.gelu(h)
    h = shard(h, "batch", None, "ffn")
    return h @ p["wo"]


def make_embedding(vocab: int, d: int) -> Param:
    return Param((vocab, d), ("vocab", "embed"), init="normal", scale=0.02)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)
