"""LM assembly: embeddings (incl. multi-codebook audio and VLM stub merge),
scanned layer segments, chunked cross-entropy, MTP head, and the three
entry points the launcher lowers:

  * ``train_loss(cfg, params, batch)``            (train_4k)
  * ``prefill(cfg, params, batch)``               (prefill_32k)
  * ``decode_step(cfg, params, batch, cache)``    (decode_32k / long_500k)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.layers import make_norm, rmsnorm, make_embedding
from repro.models.params import Param, init_params, abstract_params
from repro.sharding.rules import shard


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    kind: str              # 'blocks' | 'hybrid'
    count: int
    mixer: str = "attn"
    ffn: str = "dense"
    plan: object = None


def segments(cfg) -> list[Segment]:
    if cfg.hybrid_block:
        plan = B.HybridPlan.build(cfg)
        return [Segment("hybrid", cfg.num_layers // cfg.hybrid_block,
                        plan=plan)]
    if cfg.family == "ssm":
        return [Segment("blocks", cfg.num_layers, mixer="mamba", ffn="none")]
    mixer = "mla" if cfg.attention_kind == "mla" else "attn"
    if cfg.moe is None:
        return [Segment("blocks", cfg.num_layers, mixer=mixer, ffn="dense")]
    segs = []
    fk = cfg.moe.first_k_dense
    if fk:
        segs.append(Segment("blocks", fk, mixer=mixer, ffn="dense"))
    assert cfg.moe.every == 1, "periodic MoE outside hybrid_block unsupported"
    segs.append(Segment("blocks", cfg.num_layers - fk, mixer=mixer, ffn="moe"))
    return segs


# ---------------------------------------------------------------------------
# descriptors
# ---------------------------------------------------------------------------
def make_lm(cfg):
    d = cfg.d_model
    p: dict = {}
    p["embed"] = (Param((cfg.num_codebooks, cfg.vocab_size, d),
                        ("codebooks", "vocab", "embed"), init="normal",
                        scale=0.02)
                  if cfg.num_codebooks
                  else make_embedding(cfg.vocab_size, d))
    segs = []
    for seg in segments(cfg):
        if seg.kind == "hybrid":
            segs.append(B.stack_descr(B.make_super_block(cfg, seg.plan),
                                      seg.count))
        else:
            segs.append(B.stack_descr(B.make_block(cfg, seg.mixer, seg.ffn),
                                      seg.count))
    p["segments"] = segs
    p["final_norm"] = make_norm(d)
    if not cfg.tie_embeddings:
        p["lm_head"] = (Param((cfg.num_codebooks, d, cfg.vocab_size),
                              ("codebooks", "embed", "vocab"), init="scaled")
                        if cfg.num_codebooks
                        else Param((d, cfg.vocab_size), ("embed", "vocab"),
                                   init="scaled"))
    if cfg.mtp_depth:
        p["mtp"] = [
            {
                "norm_h": make_norm(d),
                "norm_e": make_norm(d),
                "proj": Param((2 * d, d), (None, "embed"), init="scaled"),
                "block": B.make_block(
                    cfg, "mla" if cfg.attention_kind == "mla" else "attn",
                    "dense"),
            }
            for _ in range(cfg.mtp_depth)
        ]
    return p


def init_lm(cfg, rng):
    return init_params(make_lm(cfg), rng)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def embed_tokens(cfg, params, tokens, batch=None):
    if cfg.num_codebooks:
        # tokens [B, S, cb]; embed [cb, V, d]
        tcb = jnp.moveaxis(tokens, -1, 0)  # [cb, B, S]
        h = jax.vmap(lambda tab, t: jnp.take(tab, t, axis=0))(
            params["embed"], tcb).sum(axis=0)
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.vision_stub and batch is not None and "image_embeds" in batch:
        img = batch["image_embeds"].astype(h.dtype)   # [B, N, d]
        pos = batch["image_positions"]                 # [B, N] int32
        b_idx = jnp.arange(h.shape[0])[:, None]
        h = h.at[b_idx, pos].set(img)
    return shard(h, "batch", "seq", "embed")


def head_weights(cfg, params):
    if cfg.tie_embeddings:
        return jnp.swapaxes(params["embed"], -1, -2)  # [d, V] (or [cb, d, V])
    return params["lm_head"]


def apply_head(cfg, params, h):
    w = head_weights(cfg, params)
    if cfg.num_codebooks:
        return jnp.einsum("...d,cdv->...cv", h, w)
    return h @ w


# ---------------------------------------------------------------------------
# backbone
# ---------------------------------------------------------------------------
def _segment_scan(cfg, seg: Segment, seg_params, h, positions, *,
                  remat: bool, collect: bool, unroll: bool = False):
    def body(carry, layer_p):
        hh = carry
        if seg.kind == "hybrid":
            if collect:
                hh, aux, cache = B.apply_super_block_collect(
                    cfg, layer_p, hh, positions, seg.plan)
                return hh, (aux, cache)
            hh, aux = B.apply_super_block(cfg, layer_p, hh, positions,
                                          seg.plan)
            return hh, (aux, None)
        if collect:
            hh, aux, cache = B.apply_block_collect(cfg, layer_p, hh,
                                                   positions, seg.mixer,
                                                   seg.ffn)
            return hh, (aux, cache)
        hh, aux = B.apply_block(cfg, layer_p, hh, positions, seg.mixer,
                                seg.ffn)
        return hh, (aux, None)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    h, (auxs, caches) = jax.lax.scan(
        body, h, seg_params, unroll=seg.count if unroll else 1)
    return h, jnp.sum(auxs), caches


def backbone(cfg, params, h, positions, *, remat: bool = True,
             collect: bool = False, unroll: bool = False):
    """Returns (h, aux_loss, caches-per-segment or None)."""
    aux = jnp.zeros((), jnp.float32)
    caches = []
    for seg, seg_params in zip(segments(cfg), params["segments"],
                               strict=False):
        h, a, c = _segment_scan(cfg, seg, seg_params, h, positions,
                                remat=remat, collect=collect, unroll=unroll)
        aux = aux + a
        caches.append(c)
    return h, aux, (caches if collect else None)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def _xent_chunk(cfg, params, h, targets, mask):
    """Cross-entropy for one [B, C, d] chunk, fp32. Returns (sum_loss, n)."""
    logits = apply_head(cfg, params, h).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if cfg.num_codebooks:
        nll = jnp.mean(nll, axis=-1)  # average over codebooks
    mf = mask.astype(jnp.float32)
    return jnp.sum(nll * mf), jnp.sum(mf)


def chunked_xent(cfg, params, h, targets, mask, chunk: int = 512):
    """Sequence-chunked xent: avoids materialising [B, S, V] logits."""
    import os as _os2

    chunk = int(_os2.environ.get("REPRO_XENT_CHUNK", chunk))
    Bsz, S = h.shape[0], h.shape[1]
    if S <= chunk:
        s, n = _xent_chunk(cfg, params, h, targets, mask)
        return s / jnp.maximum(n, 1.0)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    def one(carry, i):
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 1)
        s_, n_ = _xent_chunk(cfg, params, sl(h), sl(targets), sl(mask))
        return carry, (s_, n_)

    import os as _os
    _unr = n_chunks if _os.environ.get("REPRO_UNROLL_INNER") else 1
    _, (sums, counts) = jax.lax.scan(one, 0, jnp.arange(n_chunks),
                                     unroll=_unr)
    total, n = jnp.sum(sums), jnp.sum(counts)
    if rem:
        s2, n2 = _xent_chunk(cfg, params, h[:, -rem:], targets[:, -rem:],
                             mask[:, -rem:])
        total, n = total + s2, n + n2
    return total / jnp.maximum(n, 1.0)


def train_loss(cfg, params, batch, *, remat: bool = True,
               unroll: bool = False):
    """batch: tokens [B,S] (or [B,S,cb]); optional loss_mask [B,S],
    image_embeds/image_positions (vlm). Returns (loss, metrics)."""
    tokens = batch["tokens"]
    Bsz, S = tokens.shape[0], tokens.shape[1]
    positions = jnp.arange(S)[None, :]
    h = embed_tokens(cfg, params, tokens, batch)
    h, aux, _ = backbone(cfg, params, h, positions, remat=remat,
                         unroll=unroll)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones((Bsz, S), jnp.float32)
    tgt_tok = tokens[:, 1:]
    ce = chunked_xent(cfg, params, h[:, :-1],
                      tgt_tok if cfg.num_codebooks else tgt_tok,
                      mask[:, 1:])
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth:
        mtp_loss = jnp.zeros((), jnp.float32)
        h_prev = h
        for depth, mp in enumerate(params["mtp"], start=1):
            emb = embed_tokens(cfg, params, tokens, batch)
            hm_in = jnp.concatenate(
                [rmsnorm(h_prev[:, :-1], mp["norm_h"], cfg.norm_eps),
                 rmsnorm(emb[:, 1:], mp["norm_e"], cfg.norm_eps)],
                axis=-1) @ mp["proj"]
            hm, _ = B.apply_block(
                cfg, mp["block"], hm_in, positions[:, 1:],
                "mla" if cfg.attention_kind == "mla" else "attn", "dense")
            # predict token t+1+depth from position t
            d1 = depth + 1
            mtp_loss = mtp_loss + chunked_xent(
                cfg, params, hm[:, : S - d1], tokens[:, d1:],
                mask[:, d1:])
            h_prev = jnp.pad(hm, ((0, 0), (0, 1), (0, 0)))
        loss = loss + cfg.mtp_loss_weight * mtp_loss / cfg.mtp_depth
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------
def prefill(cfg, params, batch, *, unroll: bool = False):
    """Full-sequence forward returning (last-token logits, caches)."""
    tokens = batch["tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S)[None, :]
    h = embed_tokens(cfg, params, tokens, batch)
    h, _, caches = backbone(cfg, params, h, positions, remat=False,
                            collect=True, unroll=unroll)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = apply_head(cfg, params, h[:, -1])
    return logits, caches


def make_cache(cfg, batch_size: int, max_seq: int,
               paged: tuple[int, int] | None = None):
    """Descriptor tree for the decode cache (one entry per segment).

    ``paged=(num_pages, page_size)`` selects the paged layout: KV leaves
    become shared ``[num_pages, page_size, ...]`` pools addressed through
    per-slot page tables (``batch["page_table"]`` at apply time) instead
    of dense ``[batch, max_seq, ...]`` stripes; SSM/conv state keeps its
    dense O(1) per-slot layout in both."""
    out = []
    for seg in segments(cfg):
        if seg.kind == "hybrid":
            out.append(B.make_super_block_cache_paged(
                           cfg, seg.plan, batch_size, *paged,
                           stack=(seg.count,))
                       if paged is not None
                       else B.make_super_block_cache(
                           cfg, seg.plan, batch_size, max_seq,
                           stack=(seg.count,)))
        else:
            out.append(B.make_block_cache_paged(
                           cfg, seg.mixer, batch_size, *paged,
                           stack=(seg.count,))
                       if paged is not None
                       else B.make_block_cache(
                           cfg, seg.mixer, batch_size, max_seq,
                           stack=(seg.count,)))
    return out


def prefill_chunk(cfg, params, batch, cache, *, unroll: bool = False):
    """Prefill a C-token chunk into slot caches (continuous batching).

    batch: tokens [B, C(,cb)], start [B] int32 (per-slot cache offset of
    the chunk's first token), optional active [B] bool (inactive slots'
    caches pass through untouched).  No head/logits — admission runs this
    to warm the cache; the first sampled token always comes from the
    decode path.  Optional ``page_table`` [B, W] int32 selects the paged
    cache layout.  Returns new_cache only."""
    tokens, start = batch["tokens"], batch["start"]
    active = batch.get("active")
    page_table = batch.get("page_table")
    h = embed_tokens(cfg, params, tokens, batch)
    new_caches = []
    for seg, seg_params, seg_cache in zip(segments(cfg), params["segments"],
                                          cache, strict=False):
        def body(carry, xs, seg=seg):
            hh = carry
            layer_p, layer_c = xs
            hh, nc = (B.apply_super_block_prefill_chunk(
                          cfg, layer_p, hh, layer_c, start, seg.plan, active,
                          page_table)
                      if seg.kind == "hybrid"
                      else B.apply_block_prefill_chunk(
                          cfg, layer_p, hh, layer_c, start, seg.mixer,
                          seg.ffn, active, page_table))
            return hh, nc

        h, new_c = jax.lax.scan(body, h, (seg_params, seg_cache),
                                unroll=seg.count if unroll else 1)
        new_caches.append(new_c)
    return new_caches


def decode_step(cfg, params, batch, cache, *, unroll: bool = False):
    """One decode step. batch: tokens [B,1(,cb)], pos [B] int32, optional
    page_table [B, W] int32 (paged cache layout).
    Returns (logits [B, V(,cb)], new_cache)."""
    tokens, pos = batch["tokens"], batch["pos"]
    active = batch.get("active")
    page_table = batch.get("page_table")
    h = embed_tokens(cfg, params, tokens, batch)
    new_caches = []
    for seg, seg_params, seg_cache in zip(segments(cfg), params["segments"],
                                          cache, strict=False):
        def body(carry, xs, seg=seg):
            hh = carry
            layer_p, layer_c = xs
            hh, nc = (B.apply_super_block_decode(cfg, layer_p, hh, layer_c,
                                                 pos, seg.plan, active,
                                                 page_table)
                      if seg.kind == "hybrid"
                      else B.apply_block_decode(cfg, layer_p, hh, layer_c,
                                                pos, seg.mixer, seg.ffn,
                                                active, page_table))
            return hh, nc

        h, new_c = jax.lax.scan(body, h, (seg_params, seg_cache),
                                unroll=seg.count if unroll else 1)
        new_caches.append(new_c)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = apply_head(cfg, params, h[:, -1])
    return logits, new_caches
