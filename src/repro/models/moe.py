"""Mixture-of-Experts FFN.

Two dispatch strategies (selected with REPRO_MOE, default 'gather'):

* 'gather' — sorted-capacity dispatch under plain SPMD: the T*k (token,
  expert) assignments are sorted by expert id, ranked within expert via a
  running offset, and scattered into per-expert buffers [E, C, d].  Simple
  and correct, but XLA SPMD resolves the token->expert scatter with global
  gathers (the collective-bound baseline in §Perf).

* 'ep' — beyond-paper optimisation: explicit expert parallelism with
  shard_map.  Tokens stay sharded over the DP axes and are REPLICATED over
  'model'; experts are sharded over 'model'.  Each device top-k routes its
  local tokens, dispatches only to its local expert shard (local sort,
  local capacity), and a single psum over 'model' combines expert outputs.
  Per-MoE-layer collective traffic drops from O(T·d·E-shards gathers) to
  one [T_local, d] all-reduce.

Scoring: 'softmax' (classic top-k, switch-style aux loss) or 'sigmoid'
(DeepSeek-V3: sigmoid scores, top-k re-normalised).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# API drift: shard_map graduated from jax.experimental (check_rep=) to the
# top level (check_vma=); support both so the EP path runs on either side
try:
    from jax import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
except ImportError:                                    # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_rep": False}

from repro.models.layers import make_dense_ffn, apply_dense_ffn
from repro.models.params import Param
from repro.sharding.rules import current_rules, shard


def make_moe(cfg):
    d, m = cfg.d_model, cfg.moe
    p = {
        "router": Param((d, m.num_experts), ("embed", None), init="scaled",
                        dtype="float32"),
        "wi": Param((m.num_experts, d, m.d_ff_expert),
                    ("experts", "embed", None), init="scaled"),
        "wg": Param((m.num_experts, d, m.d_ff_expert),
                    ("experts", "embed", None), init="scaled"),
        "wo": Param((m.num_experts, m.d_ff_expert, d),
                    ("experts", None, "embed"), init="scaled"),
    }
    if m.num_shared_experts:
        p["shared"] = make_dense_ffn(
            cfg.replace(act="silu"), m.num_shared_experts * m.d_ff_expert)
    if m.scoring == "sigmoid":
        p["bias"] = Param((m.num_experts,), (None,), init="zeros",
                          dtype="float32")
    return p


def _route(cfg, p, x2d):
    """x2d: [T, d] -> (weights [T,k] f32, ids [T,k] i32, aux_loss f32)."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ p["router"]  # [T, E]
    if m.scoring == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["bias"][None, :]  # bias only affects selection
        _, ids = jax.lax.top_k(sel, m.top_k)
        w = jnp.take_along_axis(scores, ids, axis=1)
        w = w / (jnp.sum(w, axis=1, keepdims=True) + 1e-20)
        probs = scores / (jnp.sum(scores, axis=1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, m.top_k)
    # switch-style load-balance loss: E * sum_e f_e * p_e
    T = x2d.shape[0]
    ones = jnp.ones((T, m.top_k), jnp.float32) / (T * m.top_k)
    frac_tokens = jnp.zeros((m.num_experts,), jnp.float32).at[ids].add(ones)
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs)
    return w, ids.astype(jnp.int32), aux


def _capacity(cfg, T: int) -> int:
    m = cfg.moe
    cf = float(os.environ.get("REPRO_MOE_CF", m.capacity_factor))
    c = int(T * m.top_k * cf / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, at least 8


def _dispatch_combine(cfg, p, x2d, w, ids, *, num_experts, base_expert=0):
    """Sorted-capacity dispatch + expert einsum + weighted combine over the
    experts [base_expert, base_expert + num_experts).  Pure function of
    local data — usable both under SPMD ('gather') and inside shard_map
    ('ep', with per-shard expert slices).

    p_wi/p_wg/p_wo must already be the local expert slice when
    base_expert > 0 semantics are in play."""
    m = cfg.moe
    T, d = x2d.shape
    E, k = num_experts, m.top_k
    C = _capacity(cfg, T)

    flat_ids = ids.reshape(-1) - base_expert       # [T*k]; OOB -> dropped
    in_range = (flat_ids >= 0) & (flat_ids < E)
    flat_ids = jnp.where(in_range, flat_ids, E)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_eid = flat_ids[order]
    sorted_tok = order // k
    counts = jnp.zeros((E + 1,), jnp.int32).at[flat_ids].add(1)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k, dtype=jnp.int32) - offsets[sorted_eid]
    keep = (rank < C) & (sorted_eid < E)
    slot = jnp.where(keep, sorted_eid * C + rank, E * C)
    buf = jnp.zeros((E * C, d), x2d.dtype).at[slot].set(
        x2d[sorted_tok], mode="drop")
    buf = buf.reshape(E, C, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = jax.nn.silu(g) * h
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * C, d)

    safe_slot = jnp.where(keep, slot, 0)
    y_sorted = jnp.where(keep[:, None], y_buf[safe_slot], 0)
    y_flat = jnp.zeros((T * k, d), x2d.dtype).at[order].set(y_sorted)
    y = jnp.einsum("tkd,tk->td", y_flat.reshape(T, k, d),
                   w.astype(x2d.dtype))
    return y


def _moe_mode() -> str:
    return os.environ.get("REPRO_MOE", "gather")


def apply_moe(cfg, p, x2d):
    """x2d: [T, d]. Returns (y [T, d], aux_loss scalar)."""
    rules = current_rules()
    if _moe_mode() == "ep" and rules is not None \
            and "model" in rules.mesh.axis_names:
        return apply_moe_ep(cfg, p, x2d, rules)
    return apply_moe_gather(cfg, p, x2d)


def apply_moe_gather(cfg, p, x2d):
    """Baseline: SPMD sorted-capacity dispatch (paper-faithful layering)."""
    m = cfg.moe
    T, d = x2d.shape
    E, k = m.num_experts, m.top_k
    C = _capacity(cfg, T)
    w, ids, aux = _route(cfg, p, x2d)

    # ---- sorted-capacity dispatch -------------------------------------
    flat_ids = ids.reshape(-1)                      # [T*k]
    order = jnp.argsort(flat_ids, stable=True)      # sort by expert
    sorted_eid = flat_ids[order]
    sorted_tok = order // k
    counts = jnp.zeros((E,), jnp.int32).at[flat_ids].add(1)
    offsets = jnp.cumsum(counts) - counts           # exclusive prefix
    rank = jnp.arange(T * k, dtype=jnp.int32) - offsets[sorted_eid]
    keep = rank < C
    slot = jnp.where(keep, sorted_eid * C + rank, E * C)  # OOB -> dropped
    buf = jnp.zeros((E * C, d), x2d.dtype).at[slot].set(
        x2d[sorted_tok], mode="drop")
    buf = shard(buf.reshape(E, C, d), "experts", None, None)

    # ---- expert compute (batched over E; shards as EP) -----------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = jax.nn.silu(g) * h
    h = shard(h, "experts", None, None)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * C, d)

    # ---- combine back --------------------------------------------------
    safe_slot = jnp.where(keep, slot, 0)
    y_sorted = jnp.where(keep[:, None], y_buf[safe_slot], 0)
    y_flat = jnp.zeros((T * k, d), x2d.dtype).at[order].set(y_sorted)
    y = jnp.einsum("tkd,tk->td", y_flat.reshape(T, k, d), w.astype(x2d.dtype))

    if m.num_shared_experts:
        y = y + apply_dense_ffn(cfg, p["shared"], x2d)
    return y, aux * m.aux_loss_coef


# ---------------------------------------------------------------------------
# explicit expert parallelism (shard_map) — §Perf optimisation
# ---------------------------------------------------------------------------
def apply_moe_ep(cfg, p, x2d, rules):
    """Tokens DP-sharded / replicated over 'model'; experts sharded over
    'model'; one psum combines.  Falls back to 'gather' when the expert
    count does not divide the model axis."""
    m = cfg.moe
    mesh = rules.mesh
    ep = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    T, d = x2d.shape
    if m.num_experts % ep or T % dp_size:
        return apply_moe_gather(cfg, p, x2d)
    E_loc = m.num_experts // ep

    x2d = shard(x2d, "batch", None)  # pin layout: rows over DP, repl. model
    dp_spec = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    router = p["router"]
    bias = p.get("bias")
    wi, wg, wo = p["wi"], p["wg"], p["wo"]

    def local(x_loc, router_w, bias_w, wi_l, wg_l, wo_l):
        pp = {"router": router_w, "wi": wi_l, "wg": wg_l, "wo": wo_l}
        if bias_w is not None:
            pp["bias"] = bias_w
        w, ids, aux = _route(cfg, pp, x_loc)
        shard_id = jax.lax.axis_index("model")
        y_loc = _dispatch_combine(cfg, pp, x_loc, w, ids,
                                  num_experts=E_loc,
                                  base_expert=shard_id * E_loc)
        y = jax.lax.psum(y_loc, "model")
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux
        return y, aux

    in_specs = (
        P(dp_spec, None),            # x2d
        P(None, None),               # router
        P(None) if bias is not None else None,
        P("model", None, None),      # wi  [E, d, ff]
        P("model", None, None),      # wg
        P("model", None, None),      # wo  [E, ff, d]
    )
    fn = partial(_shard_map, mesh=mesh,
                 in_specs=in_specs,
                 out_specs=(P(dp_spec, None), P()),
                 **_SHARD_MAP_NOCHECK)(local)
    y, aux = fn(x2d, router, bias, wi, wg, wo)
    if m.num_shared_experts:
        y = y + apply_dense_ffn(cfg, p["shared"], x2d)
    return y, aux * m.aux_loss_coef
