from repro.models import lm
from repro.models.params import (Param, abstract_params, init_params,
                                 param_count, param_shardings, param_specs)

__all__ = ["lm", "Param", "abstract_params", "init_params", "param_count",
           "param_shardings", "param_specs"]
