"""The jitted training step: value_and_grad -> clip -> optimizer update.

``make_train_step`` returns a pure function suitable for jax.jit with
donated (params, opt_state); the launcher decides shardings.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.train.optimizer import clip_by_global_norm


def make_train_step(cfg, opt, lr_fn, *, clip_norm: float = 1.0,
                    remat: bool = True, compress=None, unroll: bool = False):
    """compress: optional gradient-compression transform
    (see sharding/compression.py) applied to grads before the update."""

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            loss, metrics = lm.train_loss(cfg, p, batch, remat=remat,
                                          unroll=unroll)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if compress is not None:
            grads, opt_state = compress(grads, opt_state)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def eval_step(cfg, params, batch):
    loss, metrics = lm.train_loss(cfg, params, batch, remat=False)
    return metrics
