"""Training loop with checkpoint/restart fault tolerance.

Designed so the *ExpoCloud worker* can run it as a task: if the process (or
the node) dies, re-invoking ``run_training`` with the same arguments resumes
from the latest checkpoint — the paper's `tasks_from_failed` reassignment
plus this loop's restore gives end-to-end at-least-once training progress.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.data.synthetic import DataConfig, SyntheticIterator, batch_at
from repro.models import lm
from repro.models.params import abstract_params, init_params, param_shardings
from repro.checkpoint import checkpointer as ckpt
from repro.sharding.rules import use_rules
from repro.sharding.zero import opt_state_shardings
from repro.train.optimizer import get_optimizer
from repro.train.schedule import warmup_cosine
from repro.train.train_step import make_train_step


@dataclass
class TrainJob:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    log_every: int = 10
    keep: int = 3
    base_lr: float = 3e-4
    warmup: int = 20
    clip_norm: float = 1.0
    optimizer: str = "adamw"
    remat: bool = True
    seed: int = 0
    async_ckpt: bool = True
    zero1: bool = True
    # injected fault for tests: raise after N steps (simulates preemption)
    fail_after_step: int | None = None


def run_training(cfg, data_cfg: DataConfig, job: TrainJob, *, rules=None,
                 log=print):
    """Returns (history, final_step). Restores from job.ckpt_dir if present."""
    descr = lm.make_lm(cfg)
    opt = get_optimizer(job.optimizer)
    lr_fn = warmup_cosine(job.base_lr, job.warmup, job.total_steps)
    step_fn = make_train_step(cfg, opt, lr_fn, clip_norm=job.clip_norm,
                              remat=job.remat)

    param_sh = opt_sh = None
    if rules is not None:
        param_sh = param_shardings(descr, rules)
        opt_sh = opt_state_shardings(job.optimizer, descr, rules,
                                     zero1=job.zero1)

    start_step = 0
    params = opt_state = None
    if job.ckpt_dir and ckpt.available_steps(job.ckpt_dir):
        like_p = jax.eval_shape(lambda: init_params(descr, jax.random.PRNGKey(0)))
        like_o = jax.eval_shape(opt.init, like_p)
        state, start_step, meta = ckpt.restore(
            job.ckpt_dir, {"params": like_p, "opt": like_o},
            shardings=({"params": param_sh, "opt": opt_sh}
                       if param_sh is not None else None))
        params, opt_state = state["params"], state["opt"]
        log(f"[train] restored checkpoint at step {start_step}")
    else:
        with use_rules(rules):
            params = init_params(descr, jax.random.PRNGKey(job.seed))
            opt_state = opt.init(params)
        if param_sh is not None:
            params = jax.tree_util.tree_map(jax.device_put, params, param_sh)
            opt_state = jax.tree_util.tree_map(jax.device_put, opt_state,
                                               opt_sh)

    def wrapped(params, opt_state, batch, step):
        with use_rules(rules):
            return step_fn(params, opt_state, batch, step)

    jit_kwargs = {}
    if param_sh is not None:
        jit_kwargs = dict(
            in_shardings=(param_sh, opt_sh, None, None),
            out_shardings=(param_sh, opt_sh, None),
        )
    jstep = jax.jit(wrapped, donate_argnums=(0, 1), **jit_kwargs)

    it = SyntheticIterator(data_cfg, start_step)
    history = []
    pending_writer = None
    t0 = time.time()
    for step in range(start_step, job.total_steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = jstep(params, opt_state, batch,
                                           jax.numpy.asarray(step))
        if job.fail_after_step is not None and step >= job.fail_after_step:
            raise RuntimeError(f"injected failure at step {step}")
        if (step + 1) % job.log_every == 0 or step == start_step:
            m = {k: float(v) for k, v in metrics.items()}
            history.append(dict(m, step=step))
            log(f"[train] step {step} loss={m['loss']:.4f} "
                f"lr={m['lr']:.2e} ({time.time()-t0:.1f}s)")
        if job.ckpt_dir and (step + 1) % job.ckpt_every == 0:
            if pending_writer is not None:
                pending_writer.join()
            pending_writer = ckpt.save(
                job.ckpt_dir, step + 1,
                {"params": params, "opt": opt_state},
                metadata={"arch": cfg.name, "data_state": it.state()},
                async_write=job.async_ckpt)
            ckpt.prune(job.ckpt_dir, job.keep)
    if pending_writer is not None:
        pending_writer.join()
    if job.ckpt_dir:
        w = ckpt.save(job.ckpt_dir, job.total_steps,
                      {"params": params, "opt": opt_state},
                      metadata={"arch": cfg.name, "data_state": it.state()},
                      async_write=False)
    return history, job.total_steps, params
