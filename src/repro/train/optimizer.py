"""Optimizers (no external deps): AdamW with fp32 master weights, and
Adafactor (factored second moment) for parameter-heavy models.

State layout is a plain pytree so ZeRO-1 sharding (sharding/zero.py) can
assign per-leaf shardings, and the checkpointer can save/restore it like
any other tree.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
            "master": jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params, lr):
        c = state["count"] + 1
        b1c = 1 - self.b1 ** c.astype(jnp.float32)
        b2c = 1 - self.b2 ** c.astype(jnp.float32)

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh, vh = m / b1c, v / b2c
            step = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * master
            master = master - lr * step
            return m, v, master

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     state["master"])
        m = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
        master = jax.tree_util.tree_map(lambda t: t[2], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
        new_params = jax.tree_util.tree_map(
            lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, {"m": m, "v": v, "master": master, "count": c}


# ---------------------------------------------------------------------------
# Adafactor (factored v; no master copy -> ~4 bytes/param state)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Adafactor:
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params):
        def per(p):
            if self._factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree_util.tree_map(per, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params, lr):
        c = state["count"] + 1
        rho = 1.0 - c.astype(jnp.float32) ** -self.decay

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if "vr" in v:
                vr = rho * v["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * v["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = (g / jnp.sqrt(vr / denom)[..., None]
                     / jnp.sqrt(vc)[..., None, :])
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": rho * v["v"] + (1 - rho) * g2}
                u = g / jnp.sqrt(nv["v"])
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            pf = p.astype(jnp.float32)
            pf = pf - lr * u - lr * self.weight_decay * pf
            return pf.astype(p.dtype), nv

        out = jax.tree_util.tree_map(upd, grads, state["v"], params)
        is_pair = lambda t: isinstance(t, tuple)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_pair)
        v = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_pair)
        return new_params, {"v": v, "count": c}


def get_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise KeyError(name)
