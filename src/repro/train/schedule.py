"""LR schedules: linear warmup + cosine decay (the usual)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def constant(base_lr: float):
    return lambda step: jnp.full((), base_lr, jnp.float32)
