"""Token samplers: greedy / temperature / top-k.

``sample`` is branch-free in ``temperature`` so it can be jitted with the
temperature as a *traced* argument — per-request settings then never
retrigger compilation (the seed version python-branched on the float, so
every distinct temperature was a fresh trace).  ``sample_batch`` is the
slot-vectorised variant the serving engine uses: per-slot RNG keys and
per-slot temperature/top-k vectors, one fused dispatch for the whole batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _greedy(lf):
    """argmax with a tie-break that is stable across compiled programs.

    XLA's argmax does not guarantee which index wins an *exact* tie — two
    fusions of the same logits can disagree, which breaks the engine's
    batched-vs-solo identity guarantee.  max() is order-independent and the
    integer min over tied indices is unique, so this is deterministic."""
    m = jnp.max(lf, axis=-1, keepdims=True)
    v = lf.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(v, dtype=jnp.int32), lf.shape)
    return jnp.min(jnp.where(lf == m, idx, v), axis=-1).astype(jnp.int32)


def sample(logits, rng, *, temperature=0.0, top_k: int = 0):
    """logits [..., V] -> token ids [...].

    ``temperature`` may be a python float or a traced f32 scalar;
    temperature == 0 selects greedy argmax.  ``top_k`` stays a static int
    (0 disables)."""
    lf = logits.astype(jnp.float32)
    greedy = _greedy(lf)
    temp = jnp.asarray(temperature, jnp.float32)
    scaled = lf / jnp.maximum(temp, 1e-6)
    if top_k:
        vals, _ = jax.lax.top_k(scaled, top_k)
        cutoff = vals[..., -1:]
        scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)
    drawn = jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temp > 0.0, drawn, greedy)


def sample_batch(logits, keys, temperature, top_k):
    """Per-slot batched sampling for the serving engine.

    logits: [B, V] or [B, cb, V]; keys: [B, 2] uint32 (one PRNG key per
    slot — concurrent users draw from independent streams); temperature:
    [B] f32 (0 = greedy); top_k: [B] int32 (0 = disabled, traced so mixed
    per-request settings share one compilation).  Returns int32 [B(,cb)]."""
    lf = logits.astype(jnp.float32)
    B, V = lf.shape[0], lf.shape[-1]
    lead = (B,) + (1,) * (lf.ndim - 2)       # broadcast per-slot scalars
    greedy = _greedy(lf)

    # traced per-slot top-k: k-th largest value as cutoff via a descending
    # sort (top_k <= 0 keeps everything)
    desc = jnp.flip(jnp.sort(lf, axis=-1), axis=-1)
    kidx = (jnp.clip(top_k, 1, V) - 1).reshape(*lead, 1)
    kidx = jnp.broadcast_to(kidx, (*lf.shape[:-1], 1))
    cutoff = jnp.take_along_axis(desc, kidx, axis=-1)
    use_k = (top_k > 0).reshape(*lead, 1)
    masked = jnp.where(use_k & (lf < cutoff), -jnp.inf, lf)

    temp = temperature.astype(jnp.float32).reshape(*lead, 1)
    scaled = masked / jnp.maximum(temp, 1e-6)
    drawn = jax.vmap(
        lambda key, row: jax.random.categorical(key, row, axis=-1)
    )(keys, scaled).astype(jnp.int32)
    sel = (temperature > 0.0).reshape(lead)
    return jnp.where(sel, drawn, greedy)
