"""Seeded synthetic request traces for the serving benchmark.

A trace is a list of ``TimedRequest`` with Poisson arrivals and mixed
prompt/output lengths — the "millions of users" half of the north star
reduced to a reproducible workload: same seed, same trace, so host-sync
and fused engines replay identical request streams.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TimedRequest:
    arrival_s: float
    prompt: np.ndarray          # [S] (or [S, cb]) int32
    max_new_tokens: int
    temperature: float = 0.0
    top_k: int = 0


def poisson_trace(*, n_requests: int, rate_per_s: float, vocab_size: int,
                  seed: int = 0, prompt_lens: tuple[int, int] = (4, 64),
                  output_lens: tuple[int, int] = (4, 32), codebooks: int = 0,
                  temperature: float = 0.0) -> list[TimedRequest]:
    """Poisson arrivals at ``rate_per_s`` with uniform prompt/output lengths
    (inclusive ranges).  Fully determined by ``seed``."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, n_requests))
    out = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        shape = (plen, codebooks) if codebooks else plen
        prompt = rng.integers(0, vocab_size, shape).astype(np.int32)
        out.append(TimedRequest(
            arrival_s=float(arrivals[i]), prompt=prompt,
            max_new_tokens=int(rng.integers(output_lens[0],
                                            output_lens[1] + 1)),
            temperature=temperature))
    return out
