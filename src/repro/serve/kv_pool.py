"""Paged KV block allocator: a fixed pool of ``num_pages`` pages of
``page_size`` token rows each, shared by every batch slot.

ExpoCloud's core economy is releasing resources the moment they stop
earning their keep; the dense per-slot KV stripe violates that at the
memory layer (every slot owns ``max_seq`` rows even for a 5-token
request).  The pool decouples resident memory from ``slots × max_seq``:

  * each slot holds a *page table* — logical page ``j`` of the slot maps
    to physical page ``table[slot, j]`` in the pool,
  * pages are allocated lazily as a slot's KV length crosses page
    boundaries, and freed O(1) when the request retires or is preempted
    (the free list is a plain LIFO stack),
  * the allocator is pure host-side bookkeeping — device scatter/gather
    through the (traced) page tables lives in the model layer.

Accounting is first-class: ``used_pages``, ``high_water``, per-slot
``footprint``, and alloc/free counters, so admission control and the
serve bench can reason about memory instead of worst-case provisioning.
"""
from __future__ import annotations

import numpy as np


class PoolExhausted(Exception):
    """Raised by ``alloc`` when the free list cannot cover a request."""


class KVPool:
    """Host-side page allocator for a paged KV cache.

    Parameters
    ----------
    num_pages : total physical pages in the pool.
    page_size : token rows per page.
    slots     : number of batch slots (page-table rows).
    max_seq   : engine sequence bound; fixes the page-table width at
                ``ceil(max_seq / page_size)``.
    """

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 max_seq: int):
        assert num_pages >= 1 and page_size >= 1, (num_pages, page_size)
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.width = -(-int(max_seq) // self.page_size)  # ceil
        # LIFO free list: O(1) alloc/free, no fragmentation (unit pages).
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        # table[s, j] = physical page backing the slot's logical page j.
        # Unmapped entries hold the sentinel ``num_pages``: readers mask
        # by kv_len (stale entries are never attended; gathers clamp),
        # and a write scattered through a sentinel computes an
        # out-of-range flat row and is dropped — defence in depth on top
        # of allocation preceding every write.
        self.table = np.full((self.slots, self.width), self.num_pages,
                             np.int32)
        self._owned: list[list[int]] = [[] for _ in range(self.slots)]
        self.high_water = 0
        self.total_allocs = 0
        self.total_frees = 0

    # -- accounting ----------------------------------------------------
    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def footprint(self, slot: int) -> int:
        """Pages currently owned by ``slot``."""
        return len(self._owned[slot])

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to back token rows ``0 .. n_tokens-1``."""
        return -(-max(0, int(n_tokens)) // self.page_size)

    def stats(self) -> dict:
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "used_pages": self.used_pages,
            "free_pages": self.free_pages,
            "high_water": self.high_water,
            "total_allocs": self.total_allocs,
            "total_frees": self.total_frees,
        }

    # -- allocation ----------------------------------------------------
    def needed(self, slot: int, upto_pos: int) -> int:
        """Extra pages ``slot`` needs so row ``upto_pos`` is backed."""
        want = self.pages_for(int(upto_pos) + 1)
        return max(0, want - len(self._owned[slot]))

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    def alloc(self, slot: int, upto_pos: int) -> list[int]:
        """Grow ``slot`` so token row ``upto_pos`` is backed.

        Returns the newly allocated physical page ids (possibly empty).
        Raises :class:`PoolExhausted` — allocating nothing — if the free
        list is short; callers preempt or defer and retry."""
        need = self.needed(slot, upto_pos)
        if need > len(self._free):
            raise PoolExhausted(
                f"slot {slot} needs {need} pages, {len(self._free)} free")
        owned = self._owned[slot]
        fresh = [self._free.pop() for _ in range(need)]
        for page in fresh:
            self.table[slot, len(owned)] = page
            owned.append(page)
        self.total_allocs += need
        self.high_water = max(self.high_water, self.used_pages)
        return fresh

    def free_slot(self, slot: int) -> int:
        """Release every page owned by ``slot``; O(pages owned)."""
        owned = self._owned[slot]
        n = len(owned)
        self._free.extend(owned)
        self.total_frees += n
        self._owned[slot] = []
        self.table[slot, :] = self.num_pages
        return n
