"""Batched decode engine with slot-based continuous batching.

Requests are admitted into fixed batch slots between decode steps.  Each
slot carries its own position counter (positions are a [B] vector through
the model) and an ``active`` mask: inactive slots write nothing to the KV
cache and keep their SSM/conv state frozen, so admission/retirement of one
request never perturbs the others — this is what makes continuous batching
correct for hybrid/SSM architectures, not just KV-cache transformers.

Prompt consumption here is sequential forced decode (one token per step,
per slot admission); the launcher's ``prefill`` path is the batched
alternative for long prompts.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.params import init_params
from repro.serve.sampler import sample


@dataclass
class Request:
    prompt: np.ndarray          # [S] (or [S, cb]) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: list = field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_seq: int = 512, rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.cache = init_params(lm.make_cache(cfg, batch_slots, max_seq),
                                 jax.random.PRNGKey(0))
        self.pos = np.zeros((batch_slots,), np.int32)
        self.active: list[Request | None] = [None] * batch_slots
        self.remaining = np.zeros((batch_slots,), np.int32)
        # remaining prompt tokens to force-feed, per slot
        self.pending_prompt: list[list] = [[] for _ in range(batch_slots)]
        self.rng = jax.random.PRNGKey(rng_seed)
        self.queue: list[Request] = []
        self.steps = 0

        def _step(params, cache, tokens, pos, active):
            batch = {"tokens": tokens, "pos": pos, "active": active}
            logits, new_cache = lm.decode_step(cfg, params, batch, cache)
            return logits, new_cache

        self._decode = jax.jit(_step, donate_argnums=(1,))
        self._next_tokens = np.zeros(self._tok_shape(), np.int32)

    def _tok_shape(self):
        if self.cfg.num_codebooks:
            return (self.B, 1, self.cfg.num_codebooks)
        return (self.B, 1)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.B):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                self.pos[slot] = 0
                self.remaining[slot] = req.max_new_tokens
                self.pending_prompt[slot] = list(req.prompt)
                first = self.pending_prompt[slot].pop(0)
                self._next_tokens[slot, 0] = first

    def step(self) -> int:
        """One decode step across all slots; returns #requests finished."""
        self._admit()
        live = np.array([r is not None for r in self.active])
        if not live.any():
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self._next_tokens),
            jnp.asarray(self.pos), jnp.asarray(live))
        self.steps += 1
        self.rng, sub = jax.random.split(self.rng)
        logits_np = np.asarray(logits.astype(jnp.float32))
        finished = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[slot] += 1
            if self.pending_prompt[slot]:
                # still forcing the prompt; next input is the next prompt tok
                self._next_tokens[slot, 0] = self.pending_prompt[slot].pop(0)
                continue
            tok = np.asarray(sample(jnp.asarray(logits_np[slot]), sub,
                                    temperature=req.temperature))
            req.output.append(tok.copy())
            self.remaining[slot] -= 1
            self._next_tokens[slot, 0] = tok
            if self.remaining[slot] <= 0 or self.pos[slot] >= self.max_seq - 1:
                req.done = True
                self.active[slot] = None
                finished += 1
        return finished

    def run_until_drained(self, max_steps: int = 100_000) -> int:
        while (self.queue or any(r is not None for r in self.active)) \
                and self.steps < max_steps:
            self.step()
        return self.steps
