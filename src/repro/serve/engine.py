"""Batched decode engine with slot-based continuous batching.

Requests are admitted into fixed batch slots between decode steps.  Each
slot carries its own position counter (positions are a [B] vector through
the model) and an ``active`` mask: inactive slots write nothing to the KV
cache and keep their SSM/conv state frozen, so admission/retirement of one
request never perturbs the others — this is what makes continuous batching
correct for hybrid/SSM architectures, not just KV-cache transformers.

Two stepping modes:

* ``mode="fused"`` (default): sampling runs *inside* the jitted step —
  per-slot PRNG keys split on device, temperature/top-k as traced [B]
  vectors, prompt forcing / emission / retirement bookkeeping as device
  arrays — and a ``lax.scan`` runs ``steps_per_sync`` decode steps per
  host round-trip.  The host only syncs to unpack emitted tokens and
  admit/retire requests.
* ``mode="host"``: the per-step-host-sync baseline (one decode dispatch,
  full-logits device->host transfer, per-slot python sampling per step) —
  the seed engine's cost profile with its correctness bugs fixed
  (per-slot RNG keys instead of one shared subkey, deque admission,
  single-trace sampling via a traced temperature).  Kept as the
  benchmark baseline; greedy outputs are identical across modes.

Prompt consumption is sequential forced decode by default; with
``prefill_chunk=C > 0`` admission runs batched C-token prefill chunks
into the slot's cache (``lm.prefill_chunk``) and only the remainder of
the prompt goes through forced decode, with
``max_prefill_tokens_per_sync`` bounding per-sync prefill work so decode
latency of resident slots stays flat.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.params import init_params, is_param
from repro.serve.sampler import sample, sample_batch


@dataclass
class Request:
    prompt: np.ndarray          # [S] (or [S, cb]) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    output: list = field(default_factory=list)
    done: bool = False


# ---------------------------------------------------------------------------
# module-level jits (static cfg is hashable -> engines share compilations)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _decode_once(cfg, params, cache, tokens, pos, active):
    batch = {"tokens": tokens, "pos": pos, "active": active}
    return lm.decode_step(cfg, params, batch, cache)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _prefill_chunk(cfg, params, cache, tokens, start, active):
    batch = {"tokens": tokens, "start": start, "active": active}
    return lm.prefill_chunk(cfg, params, batch, cache)


@partial(jax.jit, static_argnums=(0, 1), donate_argnums=(3, 4))
def _fused_steps(cfg, n_steps, params, cache, state, prompt_buf, temp, topk):
    """Run ``n_steps`` decode steps fully on device.

    state: {tokens [B,1(,cb)], pos/cursor/plen/remaining [B] i32,
    live [B] bool, keys [B,2] u32}.  Returns (cache, state,
    sampled [n,B(,cb)], emit [n,B]) — the host unpacks emissions in step
    order after the single sync."""
    max_seq = prompt_buf.shape[1]
    b_idx = jnp.arange(prompt_buf.shape[0])

    def body(carry, _):
        cache, st = carry
        tokens, live, pos = st["tokens"], st["live"], st["pos"]
        cursor, plen, remaining = st["cursor"], st["plen"], st["remaining"]
        batch = {"tokens": tokens, "pos": pos, "active": live}
        logits, cache = lm.decode_step(cfg, params, batch, cache)
        pos = pos + live
        ks = jax.vmap(lambda k: jax.random.split(k, 2))(st["keys"])
        keys, subs = ks[:, 0], ks[:, 1]
        # every slot advances its stream every step (dead-slot draws are
        # discarded) so a request's stream doesn't depend on neighbours
        sampled = sample_batch(logits, subs, temp, topk)     # [B(,cb)]
        forcing = cursor < plen
        forced = prompt_buf[b_idx, jnp.clip(cursor, 0, max_seq - 1)]
        sel = forcing if sampled.ndim == 1 else forcing[:, None]
        lv = live if sampled.ndim == 1 else live[:, None]
        nxt = jnp.where(lv, jnp.where(sel, forced, sampled), tokens[:, 0])
        cursor = cursor + (forcing & live)
        emit = live & ~forcing
        remaining = remaining - emit
        done_now = emit & ((remaining <= 0) | (pos >= max_seq - 1))
        st = {"tokens": nxt[:, None], "pos": pos, "cursor": cursor,
              "plen": plen, "remaining": remaining,
              "live": live & ~done_now, "keys": keys}
        return (cache, st), (sampled, emit)

    (cache, state), (sampled, emit) = jax.lax.scan(
        body, (cache, state), None, length=n_steps)
    return cache, state, sampled, emit


class DecodeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_seq: int = 512, rng_seed: int = 0, mode: str = "fused",
                 steps_per_sync: int = 8, prefill_chunk: int = 0,
                 max_prefill_tokens_per_sync: int | None = None):
        assert mode in ("fused", "host"), mode
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.mode = mode
        self.steps_per_sync = max(1, int(steps_per_sync))
        self.prefill_chunk = int(prefill_chunk)
        self.max_prefill_tokens_per_sync = max_prefill_tokens_per_sync
        self.cache = init_params(lm.make_cache(cfg, batch_slots, max_seq),
                                 jax.random.PRNGKey(0))
        B = batch_slots
        cb_tail = (cfg.num_codebooks,) if cfg.num_codebooks else ()
        self.tokens = np.zeros((B, 1, *cb_tail), np.int32)
        self.pos = np.zeros((B,), np.int32)
        self.cursor = np.zeros((B,), np.int32)
        self.plen = np.zeros((B,), np.int32)
        self.remaining = np.zeros((B,), np.int32)
        self.live = np.zeros((B,), bool)
        self.keys = np.zeros((B, 2), np.uint32)
        self.temp = np.zeros((B,), np.float32)
        self.topk = np.zeros((B,), np.int32)
        self.prompt_buf = np.zeros((B, max_seq, *cb_tail), np.int32)
        self.pf_target = np.zeros((B,), np.int32)   # tokens to chunk-prefill
        self.pf_done = np.zeros((B,), np.int32)
        self.slot_req: list[Request | None] = [None] * B
        self.queue: collections.deque[Request] = collections.deque()
        self.steps = 0
        self._root_key = jax.random.PRNGKey(rng_seed)
        self._admitted = 0

        # slot-state leaves (SSM/conv — anything without a seq_kv axis)
        # must be zeroed when a slot is reused: position masking protects
        # KV rows, but recurrent state would leak the previous occupant.
        descr = jax.tree_util.tree_leaves(
            lm.make_cache(cfg, batch_slots, max_seq), is_leaf=is_param)
        self._state_axes = tuple(
            None if "seq_kv" in p.logical else p.logical.index("batch")
            for p in descr)

        def _zero_slots(cache, mask):
            leaves, treedef = jax.tree_util.tree_flatten(cache)
            out = []
            for leaf, ax in zip(leaves, self._state_axes, strict=True):
                if ax is None:
                    out.append(leaf)
                else:
                    shape = [1] * leaf.ndim
                    shape[ax] = leaf.shape[ax]
                    out.append(jnp.where(mask.reshape(shape),
                                         jnp.zeros_like(leaf), leaf))
            return jax.tree_util.tree_unflatten(treedef, out)

        self._zero_slots = jax.jit(_zero_slots, donate_argnums=(0,))
        self._has_state = any(a is not None for a in self._state_axes)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _start_decode(self, slot: int):
        """Arm a slot for (forced-)decode after 0..pf_target prefilled."""
        q = int(self.pf_target[slot])
        self.tokens[slot, 0] = self.prompt_buf[slot, q]
        self.cursor[slot] = q + 1
        self.pos[slot] = q
        self.live[slot] = True

    def _admit(self):
        admitted = np.zeros((self.B,), bool)
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[slot] = req
                prompt = np.asarray(req.prompt, np.int32)
                L = prompt.shape[0]
                assert 1 <= L < self.max_seq, (L, self.max_seq)
                self.prompt_buf[slot, :L] = prompt
                self.plen[slot] = L
                self.remaining[slot] = req.max_new_tokens
                # per-request PRNG stream, independent of slot placement
                self.keys[slot] = np.asarray(
                    jax.random.fold_in(self._root_key, self._admitted))
                self._admitted += 1
                self.temp[slot] = req.temperature
                self.topk[slot] = req.top_k
                C = self.prefill_chunk
                # full chunks only (single prefill trace; conv state stays
                # exact) — the remainder plus the last prompt token go
                # through forced decode, so the first sampled token's
                # logits always come from the decode path
                q = ((L - 1) // C) * C if C > 0 else 0
                self.pf_target[slot] = q
                self.pf_done[slot] = 0
                if q:
                    self.live[slot] = False   # decode starts after prefill
                else:
                    self._start_decode(slot)
                admitted[slot] = True
        if admitted.any() and self._has_state:
            self.cache = self._zero_slots(self.cache, jnp.asarray(admitted))

    def _pump_prefill(self):
        C = self.prefill_chunk
        if not C:
            return
        pending = [s for s in range(self.B)
                   if self.slot_req[s] is not None
                   and self.pf_done[s] < self.pf_target[s]]
        if not pending:
            return
        budget = self.max_prefill_tokens_per_sync
        take = []
        for s in pending:
            if budget is not None and take and (len(take) + 1) * C > budget:
                break   # bound per-sync prefill work (at least one slot)
            take.append(s)
        tok = np.zeros((self.B, C, *self.tokens.shape[2:]), np.int32)
        start = np.zeros((self.B,), np.int32)
        active = np.zeros((self.B,), bool)
        for s in take:
            d = int(self.pf_done[s])
            tok[s] = self.prompt_buf[s, d:d + C]
            start[s] = d
            active[s] = True
        self.cache = _prefill_chunk(
            self.cfg, self.params, self.cache, jnp.asarray(tok),
            jnp.asarray(start), jnp.asarray(active))
        for s in take:
            self.pf_done[s] += C
            if self.pf_done[s] >= self.pf_target[s]:
                self._start_decode(s)

    # ------------------------------------------------------------------
    def _host_step(self) -> int:
        """Seed-style per-step host sync (benchmark baseline)."""
        if not self.live.any():
            return 0
        logits, self.cache = _decode_once(
            self.cfg, self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos), jnp.asarray(self.live))
        self.steps += 1
        logits_np = np.asarray(logits.astype(jnp.float32))
        finished = 0
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is None or not self.live[slot]:
                continue
            self.pos[slot] += 1
            if self.cursor[slot] < self.plen[slot]:
                self.tokens[slot, 0] = self.prompt_buf[slot,
                                                       self.cursor[slot]]
                self.cursor[slot] += 1
                continue
            key, sub = jax.random.split(jnp.asarray(self.keys[slot]))
            self.keys[slot] = np.asarray(key)
            # eager per-slot sampling on purpose: this mode is the seed
            # engine's cost profile (the benchmark baseline), minus its
            # correctness bugs — sample() itself now takes temperature as
            # a traced operand so jitted callers never retrace on it
            tok = np.asarray(sample(
                jnp.asarray(logits_np[slot]), sub,
                temperature=jnp.float32(req.temperature), top_k=req.top_k))
            req.output.append(np.array(tok))
            self.remaining[slot] -= 1
            self.tokens[slot, 0] = tok
            if self.remaining[slot] <= 0 or self.pos[slot] >= self.max_seq - 1:
                req.done = True
                self.slot_req[slot] = None
                self.live[slot] = False
                finished += 1
        return finished

    def _fused_sync(self) -> int:
        """One fused dispatch of ``steps_per_sync`` steps + one host sync."""
        if not self.live.any():
            return 0
        state = {"tokens": jnp.asarray(self.tokens),
                 "pos": jnp.asarray(self.pos),
                 "cursor": jnp.asarray(self.cursor),
                 "plen": jnp.asarray(self.plen),
                 "remaining": jnp.asarray(self.remaining),
                 "live": jnp.asarray(self.live),
                 "keys": jnp.asarray(self.keys)}
        self.cache, state, sampled, emit = _fused_steps(
            self.cfg, self.steps_per_sync, self.params, self.cache, state,
            jnp.asarray(self.prompt_buf), jnp.asarray(self.temp),
            jnp.asarray(self.topk))
        self.steps += self.steps_per_sync
        sampled = np.asarray(sampled)
        emit = np.asarray(emit)
        for s in range(self.steps_per_sync):
            for slot in np.nonzero(emit[s])[0]:
                self.slot_req[slot].output.append(np.array(sampled[s, slot]))
        self.tokens = np.array(state["tokens"])
        self.pos = np.array(state["pos"])
        self.cursor = np.array(state["cursor"])
        self.remaining = np.array(state["remaining"])
        self.keys = np.array(state["keys"])
        new_live = np.array(state["live"])
        finished = 0
        for slot in np.nonzero(self.live & ~new_live)[0]:
            self.slot_req[slot].done = True
            self.slot_req[slot] = None
            finished += 1
        self.live = new_live
        return finished

    def step(self) -> int:
        """Admission + one stepping round; returns #requests finished.

        In fused mode one round is ``steps_per_sync`` decode steps."""
        self._admit()
        self._pump_prefill()
        return self._fused_sync() if self.mode == "fused" \
            else self._host_step()

    def run_until_drained(self, max_steps: int = 100_000) -> int:
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            self.step()
        return self.steps
