"""Batched decode engine with slot-based continuous batching.

Requests are admitted into fixed batch slots between decode steps.  Each
slot carries its own position counter (positions are a [B] vector through
the model) and an ``active`` mask: inactive slots write nothing to the KV
cache and keep their SSM/conv state frozen, so admission/retirement of one
request never perturbs the others — this is what makes continuous batching
correct for hybrid/SSM architectures, not just KV-cache transformers.

Two stepping modes:

* ``mode="fused"`` (default): sampling runs *inside* the jitted step —
  per-slot PRNG keys split on device, temperature/top-k as traced [B]
  vectors, prompt forcing / emission / retirement bookkeeping as device
  arrays — and a ``lax.scan`` runs ``steps_per_sync`` decode steps per
  host round-trip.  The host only syncs to unpack emitted tokens and
  admit/retire requests.
* ``mode="host"``: the per-step-host-sync baseline (one decode dispatch,
  full-logits device->host transfer, per-slot python sampling per step) —
  the seed engine's cost profile with its correctness bugs fixed
  (per-slot RNG keys instead of one shared subkey, deque admission,
  single-trace sampling via a traced temperature).  Kept as the
  benchmark baseline; greedy outputs are identical across modes.

Two KV-cache layouts:

* ``kv_layout="dense"``: every slot owns a ``max_seq`` KV stripe — HBM
  scales with ``slots × max_seq`` even for short requests.
* ``kv_layout="paged"``: KV rides a shared pool of ``num_pages ×
  page_size`` rows (``serve/kv_pool.py``) addressed through per-slot
  page tables.  Admission is memory-aware (a request is admitted only
  when its prompt's page footprint fits), pages are allocated lazily as
  a slot's position crosses page boundaries (once per sync, covering the
  sync's worst-case advance), and retirement frees them O(1).  On pool
  exhaustion the *youngest* slot is preempted and its request requeued
  at-least-once — the oldest slot can always run to completion (the
  constructor requires ``num_pages >= ceil(max_seq/page_size)``), so the
  engine never deadlocks and every submitted request still completes.
  Greedy outputs are identical to the dense layout; a preempted
  temperature>0 request restarts on a fresh RNG stream.

Prompt consumption is sequential forced decode by default; with
``prefill_chunk=C > 0`` admission runs batched C-token prefill chunks
into the slot's cache (``lm.prefill_chunk``) and only the remainder of
the prompt goes through forced decode, with
``max_prefill_tokens_per_sync`` bounding per-sync prefill work so decode
latency of resident slots stays flat.

Malformed prompts (empty, or too long for ``max_seq``) are rejected with
a typed failure (``Request.failed`` + ``fail_reason``) instead of
crashing the engine; serving continues for everyone else.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.params import init_params, is_param
from repro.serve.kv_pool import KVPool, PoolExhausted
from repro.serve.sampler import sample, sample_batch


# paged-KV geometry served on a tune-cache miss (the pre-tuning default)
_DEFAULT_PAGE_SIZE = 16


def _resolve_page_size(cfg, batch_slots: int, max_seq: int) -> int:
    """Tuned ``page_size`` for this engine's decode geometry.

    Consults the ``repro.tune`` best-config cache under the
    ``decode_attention_paged`` key (shape = this engine's steady-state
    decode call: B=slots, Sk=max_seq, GQA geometry from cfg).  A miss —
    or a cfg without GQA attention fields (pure-SSM / MLA stacks, whose
    paged pool is not the tuned kernel) — returns the built-in default,
    keeping behavior byte-identical when no cache is present.  A tuned
    value is re-validated against the kernel's constraint
    (0 < page_size <= max_seq) so a stale entry degrades to the default."""
    kvh = getattr(cfg, "num_kv_heads", None)
    heads = getattr(cfg, "num_heads", None)
    hd = getattr(cfg, "head_dim", None)
    if not (kvh and heads and hd):
        return _DEFAULT_PAGE_SIZE
    from repro.tune import cache as tune_cache

    shape = {"b": batch_slots, "sk": max_seq, "kvh": kvh,
             "g": max(1, heads // kvh), "d": hd}
    hit = tune_cache.best_config("decode_attention_paged", shape,
                                 str(getattr(cfg, "dtype", "float32")))
    ps = int((hit or {}).get("page_size", _DEFAULT_PAGE_SIZE))
    if not 0 < ps <= max_seq:
        ps = _DEFAULT_PAGE_SIZE
    return ps


@dataclass
class Request:
    prompt: np.ndarray          # [S] (or [S, cb]) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    output: list = field(default_factory=list)
    done: bool = False
    failed: bool = False        # typed rejection (bad prompt) — never served
    fail_reason: str | None = None


# ---------------------------------------------------------------------------
# module-level jits (static cfg is hashable -> engines share compilations)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _decode_once(cfg, params, cache, tokens, pos, active, page_table):
    batch = {"tokens": tokens, "pos": pos, "active": active}
    if page_table is not None:
        batch["page_table"] = page_table
    return lm.decode_step(cfg, params, batch, cache)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _prefill_chunk(cfg, params, cache, tokens, start, active, page_table):
    batch = {"tokens": tokens, "start": start, "active": active}
    if page_table is not None:
        batch["page_table"] = page_table
    return lm.prefill_chunk(cfg, params, batch, cache)


@partial(jax.jit, static_argnums=(2,), donate_argnums=(0,))
def _zero_leaves(leaves, mask, axes):
    """Zero the slots selected by ``mask`` along each leaf's batch axis
    (axis None = leave the leaf untouched).  Module-level so the
    compilation is shared across engine instances."""
    out = []
    for leaf, ax in zip(leaves, axes, strict=True):
        if ax is None:
            out.append(leaf)
        else:
            shape = [1] * leaf.ndim
            shape[ax] = leaf.shape[ax]
            out.append(jnp.where(mask.reshape(shape),
                                 jnp.zeros_like(leaf), leaf))
    return out


@partial(jax.jit, static_argnums=(2,), donate_argnums=(0,))
def _zero_page_leaves(pool_leaves, page_ids, page_axes):
    """Zero the given physical pages of each pool leaf (page axis per
    leaf in ``page_axes``).  Out-of-range ids (the pad sentinel) drop."""
    out = []
    for leaf, pax in zip(pool_leaves, page_axes, strict=True):
        idx = (slice(None),) * pax + (page_ids,)
        zeros = jnp.zeros((*leaf.shape[:pax], page_ids.shape[0],
                           *leaf.shape[pax + 1:]), leaf.dtype)
        out.append(leaf.at[idx].set(zeros, mode="drop"))
    return out


def _gather_pool_views(leaves, pool_idx, page_axes, page_table):
    """Replace pool leaves with sync-local dense [.., B, W*ps, ..] views."""
    B, W = page_table.shape
    out = list(leaves)
    for i, pax in zip(pool_idx, page_axes, strict=True):
        leaf = leaves[i]                        # [*lead, P, ps, *tail]
        P, ps = leaf.shape[pax], leaf.shape[pax + 1]
        ptc = jnp.minimum(page_table, P - 1)    # clamp unmapped sentinels
        g = jnp.take(leaf, ptc, axis=pax)       # [*lead, B, W, ps, *tail]
        out[i] = g.reshape(*leaf.shape[:pax], B, W * ps,
                           *leaf.shape[pax + 2:])
    return out


def _scatter_rows_back(pool_leaf, view_leaf, pax, page_table, positions,
                       keep):
    """Write rows ``positions`` of the dense view back into the pool.

    positions: [B, n] logical rows the sync may have written; keep: [B, n]
    bool — dropped rows (dead slots, rows past max_seq) scatter to an
    out-of-range sentinel.  Rows a slot stopped writing mid-sync carry
    their own gathered content, so writing them back is a no-op."""
    P, ps = pool_leaf.shape[pax], pool_leaf.shape[pax + 1]
    W = page_table.shape[1]
    B, n = positions.shape
    smax = view_leaf.shape[pax + 1]
    idx = positions.reshape((1,) * pax + (B, n)
                            + (1,) * (view_leaf.ndim - pax - 2))
    vals = jnp.take_along_axis(view_leaf, jnp.clip(idx, 0, smax - 1),
                               axis=pax + 1)   # [*lead, B, n, *tail]
    pg = jnp.clip(positions // ps, 0, W - 1)
    phys = jnp.take_along_axis(page_table, pg, axis=1)          # [B, n]
    flat = jnp.where(keep, phys * ps + positions % ps, P * ps)
    rows = pool_leaf.reshape(*pool_leaf.shape[:pax], P * ps,
                             *pool_leaf.shape[pax + 2:])
    rows = rows.at[(slice(None),) * pax + (flat.reshape(-1),)].set(
        vals.reshape(*vals.shape[:pax], B * n, *vals.shape[pax + 2:]),
        mode="drop")
    return rows.reshape(pool_leaf.shape)


@partial(jax.jit, static_argnums=(0, 1, 9), donate_argnums=(3, 4))
def _fused_steps(cfg, n_steps, params, cache, state, prompt_buf, temp, topk,
                 page_table, paged_meta):
    """Run ``n_steps`` decode steps fully on device.

    state: {tokens [B,1(,cb)], pos/cursor/plen/remaining [B] i32,
    live [B] bool, keys [B,2] u32}.  page_table: [B, W] int32 or None —
    constant across the sync (the host allocator pre-extends tables to
    cover the sync's worst-case position advance).  Because the table is
    frozen, the paged layout hoists page indirection out of the step
    loop: gather each KV pool to a sync-local dense view once, run the
    *dense* decode body over it, and scatter the <= n_steps freshly
    written rows per slot back into the pool at the end — per-step cost
    is identical to the dense layout.  (The per-step paged kernel path
    stays live through ``mode="host"`` and chunked prefill.)
    paged_meta: static (pool leaf indices, page axes) locating the pool
    leaves in the flattened cache.  Returns (cache, state,
    sampled [n,B(,cb)], emit [n,B]) — the host unpacks emissions in step
    order after the single sync."""
    max_seq = prompt_buf.shape[1]
    b_idx = jnp.arange(prompt_buf.shape[0])
    pos0, live0 = state["pos"], state["live"]
    if page_table is not None:
        pool_idx, page_axes = paged_meta
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        pools = [leaves[i] for i in pool_idx]
        cache = jax.tree_util.tree_unflatten(
            treedef,
            _gather_pool_views(leaves, pool_idx, page_axes, page_table))

    def body(carry, _):
        cache, st = carry
        tokens, live, pos = st["tokens"], st["live"], st["pos"]
        cursor, plen, remaining = st["cursor"], st["plen"], st["remaining"]
        batch = {"tokens": tokens, "pos": pos, "active": live}
        logits, cache = lm.decode_step(cfg, params, batch, cache)
        pos = pos + live
        ks = jax.vmap(lambda k: jax.random.split(k, 2))(st["keys"])
        keys, subs = ks[:, 0], ks[:, 1]
        # every slot advances its stream every step (dead-slot draws are
        # discarded) so a request's stream doesn't depend on neighbours
        sampled = sample_batch(logits, subs, temp, topk)     # [B(,cb)]
        forcing = cursor < plen
        forced = prompt_buf[b_idx, jnp.clip(cursor, 0, max_seq - 1)]
        sel = forcing if sampled.ndim == 1 else forcing[:, None]
        lv = live if sampled.ndim == 1 else live[:, None]
        nxt = jnp.where(lv, jnp.where(sel, forced, sampled), tokens[:, 0])
        cursor = cursor + (forcing & live)
        emit = live & ~forcing
        remaining = remaining - emit
        done_now = emit & ((remaining <= 0) | (pos >= max_seq - 1))
        st = {"tokens": nxt[:, None], "pos": pos, "cursor": cursor,
              "plen": plen, "remaining": remaining,
              "live": live & ~done_now, "keys": keys}
        return (cache, st), (sampled, emit)

    (cache, state), (sampled, emit) = jax.lax.scan(
        body, (cache, state), None, length=n_steps)
    if page_table is not None:
        positions = pos0[:, None] + jnp.arange(n_steps)[None, :]
        keep = live0[:, None] & (positions < max_seq)
        new_leaves, _ = jax.tree_util.tree_flatten(cache)
        out = list(new_leaves)
        for i, pax, pool in zip(pool_idx, page_axes, pools, strict=True):
            out[i] = _scatter_rows_back(pool, new_leaves[i], pax,
                                        page_table, positions, keep)
        cache = jax.tree_util.tree_unflatten(treedef, out)
    return cache, state, sampled, emit


class DecodeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_seq: int = 512, rng_seed: int = 0, mode: str = "fused",
                 steps_per_sync: int = 8, prefill_chunk: int = 0,
                 max_prefill_tokens_per_sync: int | None = None,
                 kv_layout: str = "dense", page_size: int | None = None,
                 num_pages: int | None = None):
        assert mode in ("fused", "host"), mode
        assert kv_layout in ("dense", "paged"), kv_layout
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.mode = mode
        self.steps_per_sync = max(1, int(steps_per_sync))
        self.prefill_chunk = int(prefill_chunk)
        self.max_prefill_tokens_per_sync = max_prefill_tokens_per_sync
        self.kv_layout = kv_layout

        if kv_layout == "paged":
            # explicit page_size > tuned cache > default (16)
            if page_size is None:
                page_size = _resolve_page_size(cfg, batch_slots, max_seq)
            width = -(-max_seq // int(page_size))
            if num_pages is None:
                # capacity parity with the dense layout by default; size
                # the pool below slots*width for memory-aware admission
                num_pages = batch_slots * width
            assert num_pages >= width, (
                f"num_pages={num_pages} cannot back one full sequence "
                f"(need >= ceil(max_seq/page_size) = {width}); the oldest "
                "slot could deadlock")
            self.pool: KVPool | None = KVPool(num_pages, int(page_size),
                                             batch_slots, max_seq)
            self._paged_arg = (int(num_pages), int(page_size))
        else:
            self.pool = None
            self._paged_arg = None
        cache_descr = lm.make_cache(cfg, batch_slots, max_seq,
                                    paged=self._paged_arg)
        self.cache = init_params(cache_descr, jax.random.PRNGKey(0))

        B = batch_slots
        cb_tail = (cfg.num_codebooks,) if cfg.num_codebooks else ()
        self.tokens = np.zeros((B, 1, *cb_tail), np.int32)
        self.pos = np.zeros((B,), np.int32)
        self.cursor = np.zeros((B,), np.int32)
        self.plen = np.zeros((B,), np.int32)
        self.remaining = np.zeros((B,), np.int32)
        self.live = np.zeros((B,), bool)
        self.keys = np.zeros((B, 2), np.uint32)
        self.temp = np.zeros((B,), np.float32)
        self.topk = np.zeros((B,), np.int32)
        self.prompt_buf = np.zeros((B, max_seq, *cb_tail), np.int32)
        self.pf_target = np.zeros((B,), np.int32)   # tokens to chunk-prefill
        self.pf_done = np.zeros((B,), np.int32)
        self.slot_admit = np.full((B,), -1, np.int64)  # admission order
        self.slot_req: list[Request | None] = [None] * B
        self.queue: collections.deque[Request] = collections.deque()
        self.steps = 0
        self._root_key = jax.random.PRNGKey(rng_seed)
        self._admitted = 0
        self.stats = {"admissions": 0, "rejected": 0, "preemptions": 0,
                      "admit_cache_elems": 0, "peak_occupied": 0}

        # slot-state leaves (SSM/conv — anything without a seq_kv axis)
        # must be zeroed when a slot is reused: position masking protects
        # KV rows, but recurrent state would leak the previous occupant.
        descr = jax.tree_util.tree_leaves(cache_descr, is_leaf=is_param)
        self._state_axes = tuple(
            None if "seq_kv" in p.logical else p.logical.index("batch")
            for p in descr)
        self._state_idx = tuple(i for i, ax in enumerate(self._state_axes)
                                if ax is not None)
        self._has_state = bool(self._state_idx)
        self._cache_elems = sum(int(np.prod(p.shape)) for p in descr)
        self._state_elems = sum(int(np.prod(descr[i].shape))
                                for i in self._state_idx)

        if kv_layout == "paged":
            # paged admission touches *only* the O(1) per-slot state
            # leaves (KV pool pages are re-zeroed on allocation instead,
            # so admission cost is independent of max_seq); dense keeps
            # the seed behaviour — the admission jit round-trips every
            # cache leaf, KV stripes included.
            # pool leaves: page axis sits just before the page_seq axis
            self._pool_idx = tuple(i for i, ax in enumerate(self._state_axes)
                                   if ax is None)
            self._pool_page_ax = tuple(
                descr[i].logical.index("seq_kv") - 1 for i in self._pool_idx)
            self._page_elems = sum(
                int(np.prod(descr[i].shape)) // descr[i].shape[
                    descr[i].logical.index("seq_kv") - 1]
                for i in self._pool_idx)   # elems zeroed per page
            self._paged_meta = (self._pool_idx, self._pool_page_ax)
            self._pt_dev = jnp.asarray(self.pool.table)
            self._pt_stale = False
        else:
            self._paged_meta = None

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def kv_stats(self) -> dict:
        """Accounting surface: engine counters + pool occupancy."""
        out = dict(self.stats)
        out["kv_layout"] = self.kv_layout
        out["cache_elems"] = self._cache_elems
        if self.pool is not None:
            out.update(self.pool.stats())
            out["slot_footprint"] = [self.pool.footprint(s)
                                     for s in range(self.B)]
        return out

    # -- paged-pool plumbing -------------------------------------------
    def _sync_page_table(self):
        if self._pt_stale:
            self._pt_dev = jnp.asarray(self.pool.table)
            self._pt_stale = False

    def _flush_dirty_pages(self, dirty: list[int]):
        """Zero freshly allocated pages (they may carry a previous
        occupant's rows).  Cost is proportional to pages allocated —
        never to max_seq.  Padded to a power of two so the jit traces
        O(log pool) distinct shapes; the pad sentinel is out of range
        and dropped."""
        if not dirty:
            return
        n = 1
        while n < len(dirty):
            n *= 2
        ids = np.full((n,), self.pool.num_pages, np.int32)
        ids[:len(dirty)] = dirty
        leaves, treedef = jax.tree_util.tree_flatten(self.cache)
        pool_leaves = [leaves[i] for i in self._pool_idx]
        new_pool = _zero_page_leaves(pool_leaves, jnp.asarray(ids),
                                     self._pool_page_ax)
        for i, leaf in zip(self._pool_idx, new_pool, strict=True):
            leaves[i] = leaf
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)
        self.stats["admit_cache_elems"] += len(dirty) * self._page_elems

    def _preempt(self, slot: int):
        """Evict ``slot`` on pool exhaustion: free its pages O(1) and
        requeue its request at-least-once (output restarts from the
        prompt on readmission; a temperature>0 request resamples on a
        fresh RNG stream)."""
        req = self.slot_req[slot]
        self.pool.free_slot(slot)
        self._pt_stale = True
        self.slot_req[slot] = None
        self.live[slot] = False
        self.pf_target[slot] = 0
        self.pf_done[slot] = 0
        self.slot_admit[slot] = -1
        req.output.clear()
        req.done = False
        self.queue.appendleft(req)
        self.stats["preemptions"] += 1

    def _reclaim_for(self, slot: int, upto_pos: int) -> list[int] | None:
        """Extend ``slot``'s page table to back ``upto_pos``, preempting
        *younger* occupied slots while the free list is short.  Returns
        the fresh page ids, or None if ``slot`` itself had to be
        preempted (it was the youngest).  The oldest occupied slot always
        succeeds (num_pages >= pages-per-sequence), so the engine makes
        progress and every request eventually completes."""
        while True:
            try:
                fresh = self.pool.alloc(slot, upto_pos)
                if fresh:
                    self._pt_stale = True
                return fresh
            except PoolExhausted:
                victims = [s for s in range(self.B)
                           if self.slot_req[s] is not None
                           and self.slot_admit[s] > self.slot_admit[slot]]
                if not victims:
                    self._preempt(slot)
                    return None
                self._preempt(max(victims, key=lambda s: self.slot_admit[s]))

    def _ensure_decode_pages(self, n_steps: int):
        """Pre-sync allocation: back every live slot's worst-case position
        advance (``pos .. pos+n_steps-1``) so page-boundary crossings
        inside the fused scan never fault.  Oldest slots claim first."""
        dirty: list[int] = []
        order = sorted((s for s in range(self.B) if self.live[s]),
                       key=lambda s: self.slot_admit[s])
        for s in order:
            if not self.live[s]:        # preempted by an older claimant
                continue
            upto = min(int(self.pos[s]) + n_steps - 1, self.max_seq - 1)
            fresh = self._reclaim_for(s, upto)
            if fresh:
                dirty.extend(fresh)
        self._flush_dirty_pages(dirty)
        self._sync_page_table()

    # ------------------------------------------------------------------
    def _start_decode(self, slot: int):
        """Arm a slot for (forced-)decode after 0..pf_target prefilled."""
        q = int(self.pf_target[slot])
        self.tokens[slot, 0] = self.prompt_buf[slot, q]
        self.cursor[slot] = q + 1
        self.pos[slot] = q
        self.live[slot] = True

    def _reject(self, req: Request, reason: str):
        req.failed = True
        req.done = True
        req.fail_reason = reason
        self.stats["rejected"] += 1

    def _admit(self):
        admitted = np.zeros((self.B,), bool)
        free_slots = (s for s in range(self.B) if self.slot_req[s] is None)
        while self.queue:
            req = self.queue[0]
            prompt = np.asarray(req.prompt, np.int32)
            L = prompt.shape[0]
            if not 1 <= L < self.max_seq:
                # typed rejection instead of the seed's assert: the
                # engine keeps serving everyone else
                self.queue.popleft()
                self._reject(req, f"prompt length {L} outside "
                                  f"[1, max_seq={self.max_seq})")
                continue
            if self.pool is not None \
                    and self.pool.pages_for(L) > self.pool.free_pages:
                break   # memory-aware: head request's footprint must fit
                        # (FIFO — later requests don't jump the queue)
            slot = next(free_slots, None)
            if slot is None:
                break
            self.queue.popleft()
            self.slot_req[slot] = req
            self.slot_admit[slot] = self._admitted
            self.prompt_buf[slot, :L] = prompt
            self.plen[slot] = L
            self.remaining[slot] = req.max_new_tokens
            # per-request PRNG stream, independent of slot placement
            self.keys[slot] = np.asarray(
                jax.random.fold_in(self._root_key, self._admitted))
            self._admitted += 1
            self.stats["admissions"] += 1
            self.temp[slot] = req.temperature
            self.topk[slot] = req.top_k
            C = self.prefill_chunk
            # full chunks only (single prefill trace; conv state stays
            # exact) — the remainder plus the last prompt token go
            # through forced decode, so the first sampled token's
            # logits always come from the decode path
            q = ((L - 1) // C) * C if C > 0 else 0
            self.pf_target[slot] = q
            self.pf_done[slot] = 0
            if q:
                self.live[slot] = False   # decode starts after prefill
            else:
                self._start_decode(slot)
            admitted[slot] = True
        if admitted.any() and self._has_state:
            mask = jnp.asarray(admitted)
            leaves, treedef = jax.tree_util.tree_flatten(self.cache)
            if self.kv_layout == "dense":
                # full-cache round trip (KV stripes ride along unchanged)
                self.cache = jax.tree_util.tree_unflatten(
                    treedef, _zero_leaves(leaves, mask, self._state_axes))
                self.stats["admit_cache_elems"] += self._cache_elems
            else:
                state_axes = tuple(self._state_axes[i]
                                   for i in self._state_idx)
                state = _zero_leaves([leaves[i] for i in self._state_idx],
                                     mask, state_axes)
                for i, leaf in zip(self._state_idx, state, strict=True):
                    leaves[i] = leaf
                self.cache = jax.tree_util.tree_unflatten(treedef, leaves)
                self.stats["admit_cache_elems"] += self._state_elems
        occupied = sum(r is not None for r in self.slot_req)
        self.stats["peak_occupied"] = max(self.stats["peak_occupied"],
                                          occupied)

    def _pump_prefill(self):
        C = self.prefill_chunk
        if not C:
            return
        pending = [s for s in range(self.B)
                   if self.slot_req[s] is not None
                   and self.pf_done[s] < self.pf_target[s]]
        if not pending:
            return
        budget = self.max_prefill_tokens_per_sync
        pending.sort(key=lambda s: self.slot_admit[s])
        take = []
        dirty: list[int] = []
        for s in pending:
            if budget is not None and take and (len(take) + 1) * C > budget:
                break   # bound per-sync prefill work (at least one slot)
            if self.pool is not None:
                fresh = self._reclaim_for(s, int(self.pf_done[s]) + C - 1)
                if fresh is None:
                    continue            # preempted (youngest) — requeued
                dirty.extend(fresh)
            take.append(s)
        if self.pool is not None:
            self._flush_dirty_pages(dirty)
            self._sync_page_table()
        if not take:
            return
        tok = np.zeros((self.B, C, *self.tokens.shape[2:]), np.int32)
        start = np.zeros((self.B,), np.int32)
        active = np.zeros((self.B,), bool)
        for s in take:
            d = int(self.pf_done[s])
            tok[s] = self.prompt_buf[s, d:d + C]
            start[s] = d
            active[s] = True
        self.cache = _prefill_chunk(
            self.cfg, self.params, self.cache, jnp.asarray(tok),
            jnp.asarray(start), jnp.asarray(active),
            self._pt_dev if self.pool is not None else None)
        for s in take:
            self.pf_done[s] += C
            if self.pf_done[s] >= self.pf_target[s]:
                self._start_decode(s)

    def _retire(self, slot: int):
        self.slot_req[slot].done = True
        self.slot_req[slot] = None
        self.slot_admit[slot] = -1
        if self.pool is not None:
            self.pool.free_slot(slot)   # O(1) free-on-retirement
            self._pt_stale = True

    # ------------------------------------------------------------------
    def _host_step(self) -> int:
        """Seed-style per-step host sync (benchmark baseline)."""
        if not self.live.any():
            return 0
        if self.pool is not None:
            self._ensure_decode_pages(1)
        if not self.live.any():         # everyone preempted (tiny pool)
            return 0
        logits, self.cache = _decode_once(
            self.cfg, self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos), jnp.asarray(self.live),
            self._pt_dev if self.pool is not None else None)
        self.steps += 1
        logits_np = np.asarray(logits.astype(jnp.float32))
        finished = 0
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is None or not self.live[slot]:
                continue
            self.pos[slot] += 1
            if self.cursor[slot] < self.plen[slot]:
                self.tokens[slot, 0] = self.prompt_buf[slot,
                                                       self.cursor[slot]]
                self.cursor[slot] += 1
                continue
            key, sub = jax.random.split(jnp.asarray(self.keys[slot]))
            self.keys[slot] = np.asarray(key)
            # eager per-slot sampling on purpose: this mode is the seed
            # engine's cost profile (the benchmark baseline), minus its
            # correctness bugs — sample() itself now takes temperature as
            # a traced operand so jitted callers never retrace on it
            tok = np.asarray(sample(
                jnp.asarray(logits_np[slot]), sub,
                temperature=jnp.float32(req.temperature), top_k=req.top_k))
            req.output.append(np.array(tok))
            self.remaining[slot] -= 1
            self.tokens[slot, 0] = tok
            if self.remaining[slot] <= 0 or self.pos[slot] >= self.max_seq - 1:
                self.live[slot] = False
                self._retire(slot)
                finished += 1
        return finished

    def _fused_sync(self) -> int:
        """One fused dispatch of ``steps_per_sync`` steps + one host sync."""
        if not self.live.any():
            return 0
        if self.pool is not None:
            self._ensure_decode_pages(self.steps_per_sync)
        if not self.live.any():         # everyone preempted (tiny pool)
            return 0
        state = {"tokens": jnp.asarray(self.tokens),
                 "pos": jnp.asarray(self.pos),
                 "cursor": jnp.asarray(self.cursor),
                 "plen": jnp.asarray(self.plen),
                 "remaining": jnp.asarray(self.remaining),
                 "live": jnp.asarray(self.live),
                 "keys": jnp.asarray(self.keys)}
        self.cache, state, sampled, emit = _fused_steps(
            self.cfg, self.steps_per_sync, self.params, self.cache, state,
            jnp.asarray(self.prompt_buf), jnp.asarray(self.temp),
            jnp.asarray(self.topk),
            self._pt_dev if self.pool is not None else None,
            self._paged_meta)
        self.steps += self.steps_per_sync
        sampled = np.asarray(sampled)
        emit = np.asarray(emit)
        for s in range(self.steps_per_sync):
            for slot in np.nonzero(emit[s])[0]:
                self.slot_req[slot].output.append(np.array(sampled[s, slot]))
        self.tokens = np.array(state["tokens"])
        self.pos = np.array(state["pos"])
        self.cursor = np.array(state["cursor"])
        self.remaining = np.array(state["remaining"])
        self.keys = np.array(state["keys"])
        new_live = np.array(state["live"])
        finished = 0
        for slot in np.nonzero(self.live & ~new_live)[0]:
            self._retire(slot)
            finished += 1
        self.live = new_live
        return finished

    def step(self) -> int:
        """Admission + one stepping round; returns #requests finished.

        In fused mode one round is ``steps_per_sync`` decode steps."""
        self._admit()
        self._pump_prefill()
        return self._fused_sync() if self.mode == "fused" \
            else self._host_step()

    def run_until_drained(self, max_steps: int = 100_000) -> int:
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            self.step()
        return self.steps
