"""Sq=1 decode-attention Pallas kernel vs the pure-jnp reference.

Runs the kernel in interpret mode (CPU CI); covers GQA group ratios,
ragged per-slot kv lengths and Sk that does not divide block_k (the
wrapper zero-pads and the in-kernel mask must keep the tail dead).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_paged)
from repro.kernels.ref import (decode_attention_paged_ref,
                               decode_attention_ref, gather_pages)


def _inputs(B, Sk, H, K, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, K, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("H,K", [(4, 4), (8, 2), (8, 1)])
def test_gqa_ratios(H, K):
    B, Sk, D = 2, 64, 32
    q, k, v = _inputs(B, Sk, H, K, D)
    kv_len = jnp.array([Sk, Sk], jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_k=32, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_ragged_kv_len_masks_cache_tail():
    B, Sk, H, K, D = 4, 96, 8, 2, 32
    q, k, v = _inputs(B, Sk, H, K, D, seed=1)
    kv_len = jnp.array([1, 17, 32, 96], jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_k=32, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # tail beyond kv_len must not influence the output at all
    k2 = k.at[:, 40:].set(1e4)
    v2 = v.at[:, 40:].set(-1e4)
    got2 = decode_attention(q[:2], k2[:2], v2[:2], kv_len[:2],
                            block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(got[:2]))


@pytest.mark.parametrize("Sk,block_k", [(7, 4), (100, 32), (130, 128)])
def test_non_dividing_sk(Sk, block_k):
    B, H, K, D = 2, 4, 2, 16
    q, k, v = _inputs(B, Sk, H, K, D, seed=2)
    kv_len = jnp.array([Sk, max(1, Sk // 3)], jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_k=block_k, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_scale_override_and_vdim():
    B, Sk, H, K, D = 2, 32, 4, 2, 16
    q, k, v = _inputs(B, Sk, H, K, D, seed=3)
    kv_len = jnp.array([5, 32], jnp.int32)
    got = decode_attention(q, k, v, kv_len, scale=0.25, block_k=16,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_ops_dispatch_ref_matches_kernel(monkeypatch):
    from repro.kernels import ops
    B, Sk, H, K, D = 2, 48, 4, 2, 16
    q, k, v = _inputs(B, Sk, H, K, D, seed=4)
    kv_len = jnp.array([9, 48], jnp.int32)
    monkeypatch.setenv("REPRO_PALLAS", "ref")
    via_ref = ops.decode_attention(q, k, v, kv_len)
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    via_kernel = ops.decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(via_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged variant: K/V live in a [P, ps, K, D] pool, steered by page tables
# ---------------------------------------------------------------------------
def _paged_inputs(B, W, ps, H, K, D, num_pages, seed=0):
    """Pool + *shuffled* page tables: each slot's pages are scattered over
    the pool so physical contiguity can't mask indexing bugs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k_pool = jax.random.normal(ks[1], (num_pages, ps, K, D), jnp.float32)
    v_pool = jax.random.normal(ks[2], (num_pages, ps, K, D), jnp.float32)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_pages)[:B * W]
    table = jnp.asarray(perm.reshape(B, W).astype(np.int32))
    return q, k_pool, v_pool, table


@pytest.mark.parametrize("H,K", [(4, 4), (8, 2), (8, 1)])
def test_paged_matches_paged_ref(H, K):
    B, W, ps, D = 2, 4, 8, 32
    q, kp, vp, pt = _paged_inputs(B, W, ps, H, K, D, num_pages=16)
    kv_len = jnp.array([W * ps, 11], jnp.int32)   # full + partial last page
    got = decode_attention_paged(q, kp, vp, pt, kv_len, interpret=True)
    ref = decode_attention_paged_ref(q, kp, vp, pt, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_matches_dense_on_gathered_layout():
    """The paged kernel over a shuffled table must equal the dense ref over
    the gathered [B, W*ps, K, D] view — same math, different addressing."""
    B, W, ps, H, K, D = 3, 5, 4, 8, 2, 16
    q, kp, vp, pt = _paged_inputs(B, W, ps, H, K, D, num_pages=32, seed=1)
    kv_len = jnp.array([1, 7, 20], jnp.int32)
    got = decode_attention_paged(q, kp, vp, pt, kv_len, interpret=True)
    ref = decode_attention_ref(q, gather_pages(kp, pt),
                               gather_pages(vp, pt), kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_masks_unwritten_page_tail():
    """Rows past kv_len — the unfilled tail of the last page and whole
    unread pages — must not influence the output, even when poisoned."""
    B, W, ps, H, K, D = 2, 4, 8, 4, 2, 16
    q, kp, vp, pt = _paged_inputs(B, W, ps, H, K, D, num_pages=16, seed=2)
    kv_len = jnp.array([5, 13], jnp.int32)
    base = decode_attention_paged(q, kp, vp, pt, kv_len, interpret=True)
    # poison every row of every page, then restore only the live prefixes
    kp2, vp2 = kp, vp
    for b in range(B):
        live = int(kv_len[b])
        for j in range(W):
            lo, hi = j * ps, min((j + 1) * ps, live)
            pg = int(pt[b, j])
            keep_k = kp[pg, :max(0, hi - lo)]
            keep_v = vp[pg, :max(0, hi - lo)]
            kp2 = kp2.at[pg].set(1e4).at[pg, :max(0, hi - lo)].set(keep_k)
            vp2 = vp2.at[pg].set(-1e4).at[pg, :max(0, hi - lo)].set(keep_v)
    got = decode_attention_paged(q, kp2, vp2, pt, kv_len, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_paged_sentinel_table_entries_are_safe():
    """Unmapped table entries hold the out-of-range sentinel num_pages;
    both kernel and ref must clamp (not NaN-fill) since those rows sit
    beyond kv_len anyway."""
    B, W, ps, H, K, D = 2, 4, 4, 4, 2, 16
    q, kp, vp, pt = _paged_inputs(B, W, ps, H, K, D, num_pages=8, seed=3)
    kv_len = jnp.array([3, 6], jnp.int32)
    pt = pt.at[0, 1:].set(8).at[1, 2:].set(8)      # sentinel == num_pages
    got = decode_attention_paged(q, kp, vp, pt, kv_len, interpret=True)
    ref = decode_attention_paged_ref(q, kp, vp, pt, kv_len)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_ops_dispatch_paged(monkeypatch):
    from repro.kernels import ops
    B, W, ps, H, K, D = 2, 3, 8, 4, 2, 16
    q, kp, vp, pt = _paged_inputs(B, W, ps, H, K, D, num_pages=8, seed=4)
    kv_len = jnp.array([9, 24], jnp.int32)
    monkeypatch.setenv("REPRO_PALLAS", "ref")
    via_ref = ops.decode_attention_paged(q, kp, vp, pt, kv_len)
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    via_kernel = ops.decode_attention_paged(q, kp, vp, pt, kv_len)
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(via_ref),
                               atol=2e-5, rtol=2e-5)
