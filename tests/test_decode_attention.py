"""Sq=1 decode-attention Pallas kernel vs the pure-jnp reference.

Runs the kernel in interpret mode (CPU CI); covers GQA group ratios,
ragged per-slot kv lengths and Sk that does not divide block_k (the
wrapper zero-pads and the in-kernel mask must keep the tail dead).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.ref import decode_attention_ref


def _inputs(B, Sk, H, K, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, K, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("H,K", [(4, 4), (8, 2), (8, 1)])
def test_gqa_ratios(H, K):
    B, Sk, D = 2, 64, 32
    q, k, v = _inputs(B, Sk, H, K, D)
    kv_len = jnp.array([Sk, Sk], jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_k=32, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_ragged_kv_len_masks_cache_tail():
    B, Sk, H, K, D = 4, 96, 8, 2, 32
    q, k, v = _inputs(B, Sk, H, K, D, seed=1)
    kv_len = jnp.array([1, 17, 32, 96], jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_k=32, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # tail beyond kv_len must not influence the output at all
    k2 = k.at[:, 40:].set(1e4)
    v2 = v.at[:, 40:].set(-1e4)
    got2 = decode_attention(q[:2], k2[:2], v2[:2], kv_len[:2],
                            block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(got[:2]))


@pytest.mark.parametrize("Sk,block_k", [(7, 4), (100, 32), (130, 128)])
def test_non_dividing_sk(Sk, block_k):
    B, H, K, D = 2, 4, 2, 16
    q, k, v = _inputs(B, Sk, H, K, D, seed=2)
    kv_len = jnp.array([Sk, max(1, Sk // 3)], jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_k=block_k, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_scale_override_and_vdim():
    B, Sk, H, K, D = 2, 32, 4, 2, 16
    q, k, v = _inputs(B, Sk, H, K, D, seed=3)
    kv_len = jnp.array([5, 32], jnp.int32)
    got = decode_attention(q, k, v, kv_len, scale=0.25, block_k=16,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_ops_dispatch_ref_matches_kernel(monkeypatch):
    from repro.kernels import ops
    B, Sk, H, K, D = 2, 48, 4, 2, 16
    q, k, v = _inputs(B, Sk, H, K, D, seed=4)
    kv_len = jnp.array([9, 48], jnp.int32)
    monkeypatch.setenv("REPRO_PALLAS", "ref")
    via_ref = ops.decode_attention(q, k, v, kv_len)
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    via_kernel = ops.decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(via_ref),
                               atol=2e-5, rtol=2e-5)
