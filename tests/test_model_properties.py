"""Property tests on model invariants (hypothesis + targeted)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_shape, reduced_config
from repro.configs.analysis import hardness_tuple, model_flops, param_counts
from repro.models.rope import apply_rope


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    r = apply_rope(x, jnp.arange(8), theta=10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(r, axis=-1), rtol=1e-5)


def test_rope_relative_position_property():
    """<R(p)q, R(p+d)k> depends only on d (the RoPE defining property)."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(p, d):
        rq = apply_rope(q, jnp.array([p]), theta=100.0)
        rk = apply_rope(k, jnp.array([p + d]), theta=100.0)
        return float(jnp.sum(rq * rk))
    for d in (0, 3, 7):
        vals = [dot_at(p, d) for p in (0, 5, 11)]
        np.testing.assert_allclose(vals, vals[0], rtol=1e-4, atol=1e-4)


def test_rope_partial_keeps_tail_identity():
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 2, 32))
    r = apply_rope(x, jnp.arange(4), rotary_dim=16)
    np.testing.assert_array_equal(np.asarray(r[..., 16:]),
                                  np.asarray(x[..., 16:]))


def test_rope_interleaved_differs_from_half_split():
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 1, 16))
    a = apply_rope(x, jnp.arange(4), interleaved=False)
    b = apply_rope(x, jnp.arange(4), interleaved=True)
    assert not np.allclose(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# causality
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-130m"])
def test_causality_future_tokens_do_not_affect_past(arch):
    """Changing token t must not change logits at positions < t."""
    from repro.models import lm
    from repro.models.params import init_params

    cfg = reduced_config(arch).replace(dtype="float32")
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    S = 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                             cfg.vocab_size)
    tok2 = tok.at[0, S - 1].set((tok[0, S - 1] + 7) % cfg.vocab_size)

    def logits_fn(t):
        h = lm.embed_tokens(cfg, params, t)
        h, _, _ = lm.backbone(cfg, params, h, jnp.arange(S)[None, :],
                              remat=False)
        from repro.models.layers import rmsnorm
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return lm.apply_head(cfg, params, h)

    l1, l2 = logits_fn(tok), logits_fn(tok2)
    np.testing.assert_allclose(np.asarray(l1[:, :S - 1]),
                               np.asarray(l2[:, :S - 1]), atol=1e-4)
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))


# ---------------------------------------------------------------------------
# static analysis (hardness) properties
# ---------------------------------------------------------------------------
def test_hardness_monotone_in_shape():
    """Bigger shapes must dominate smaller ones for the same arch —
    required for domino pruning across the exploration grid."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        t4 = hardness_tuple(cfg, get_shape("train_4k"))
        # train_4k vs itself with a bigger synthetic batch
        from repro.configs.shapes import ShapeConfig
        bigger = ShapeConfig("x", 4096, 512, "train")
        tb = hardness_tuple(cfg, bigger)
        assert all(b >= a for a, b in zip(t4, tb, strict=True)), arch


def test_model_flops_scale_with_tokens():
    cfg = get_config("qwen3-4b")
    from repro.configs.shapes import ShapeConfig
    f1 = model_flops(cfg, ShapeConfig("a", 1024, 8, "train"))
    f2 = model_flops(cfg, ShapeConfig("b", 1024, 16, "train"))
    assert abs(f2 / f1 - 2.0) < 0.01


def test_moe_active_params_much_smaller_than_total():
    pc = param_counts(get_config("deepseek-v3-671b"))
    assert pc.active < pc.total / 10


@given(st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_capacity_is_sufficient_for_uniform_routing(e_pow, k):
    """capacity * E >= T * k (no drops under perfectly uniform routing)."""
    from repro.models.moe import _capacity

    cfg = reduced_config("olmoe-1b-7b")
    T = 64 * e_pow
    c = _capacity(cfg, T)
    E = cfg.moe.num_experts
    assert c * E >= T * cfg.moe.top_k
