"""Per-architecture smoke tests (assignment deliverable): instantiate the
REDUCED config of each family and run one forward/train step on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.configs.analysis import param_counts
from repro.models import lm
from repro.models.params import init_params, param_count


def make_batch(cfg, B=2, S=64, rng=None):
    rng = rng or jax.random.PRNGKey(1)
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    tok = jax.random.randint(rng, shape, 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.vision_stub:
        batch["image_embeds"] = jnp.zeros(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        batch["image_positions"] = jnp.tile(
            jnp.arange(cfg.num_image_tokens), (B, 1)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = reduced_config(arch)
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.train_loss(cfg, p, b))(
        params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, float(loss))
    # gradient flows and is finite
    g = jax.grad(lambda p: lm.train_loss(cfg, p, batch)[0])(params)
    gn = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(g))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_and_decode(arch):
    cfg = reduced_config(arch)
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    logits, pf_cache = jax.jit(lambda p, b: lm.prefill(cfg, p, b))(
        params, batch)
    if cfg.num_codebooks:
        assert logits.shape == (B, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    cache = init_params(lm.make_cache(cfg, B, S + 8), jax.random.PRNGKey(2))
    db = {"tokens": batch["tokens"][:, :1],
          "pos": jnp.zeros((B,), jnp.int32)}
    dlogits, new_cache = jax.jit(lambda p, b, c: lm.decode_step(cfg, p, b, c))(
        params, db, cache)
    assert bool(jnp.all(jnp.isfinite(dlogits.astype(jnp.float32))))
    # cache structure is preserved by the scan
    jax.tree_util.tree_map(lambda a, b: (a.shape, b.shape), cache, new_cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_descriptor_count_matches_analysis(arch):
    """The static analysis (used for hardness + MODEL_FLOPS) must agree
    with the actual parameter tree to within 2%."""
    from repro.configs import get_config

    cfg = get_config(arch)
    descr = lm.make_lm(cfg)
    actual = param_count(descr)
    predicted = param_counts(cfg).total
    assert abs(actual - predicted) / predicted < 0.02, \
        (arch, actual, predicted)


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-130m",
                                  "chatglm3-6b", "jamba-v0.1-52b"])
def test_decode_matches_teacher_forcing(arch):
    """Sequential decode with cache == full-sequence forward (the KV-cache /
    SSM-state correctness test), at fp32 tolerance."""
    import dataclasses

    cfg = reduced_config(arch).replace(dtype="float32")
    if cfg.moe is not None:
        # ample capacity: the full (teacher-forcing) pass drops tokens at
        # expert-capacity overflow, decode (1 token) never does — that
        # difference is GShard-dropping semantics, not a bug; remove it
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    # run the equivalence in true fp32 (bf16 params would accumulate
    # ~5e-2 drift over the decode steps, masking real bugs)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)
    B, S = 1, 16
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)
    # full forward logits at each position
    positions = jnp.arange(S)[None, :]
    h = lm.embed_tokens(cfg, params, tok)
    h, _, _ = lm.backbone(cfg, params, h, positions, remat=False)
    h = lm.apply_head(cfg, params,
                      jax.vmap(lambda x: x)(h))
    import repro.models.layers as L

    h_norm = L.rmsnorm(
        lm.backbone(cfg, params, lm.embed_tokens(cfg, params, tok),
                    positions, remat=False)[0],
        params["final_norm"], cfg.norm_eps)
    full_logits = lm.apply_head(cfg, params, h_norm)  # [B, S, V]

    cache = init_params(lm.make_cache(cfg, B, S), jax.random.PRNGKey(2))
    step = jax.jit(lambda p, b, c: lm.decode_step(cfg, p, b, c))
    for t in range(S):
        db = {"tokens": tok[:, t:t + 1],
              "pos": jnp.full((B,), t, jnp.int32)}
        dlogits, cache = step(params, db, cache)
        import numpy as np

        np.testing.assert_allclose(
            np.asarray(dlogits[0], np.float32),
            np.asarray(full_logits[0, t], np.float32),
            atol=2e-3, rtol=2e-3, err_msg=f"{arch} t={t}")
