"""Data pipeline determinism + optimizer correctness + schedules +
gradient compression (error feedback)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import DataConfig, SyntheticIterator, batch_at
from repro.train.optimizer import AdamW, Adafactor, clip_by_global_norm
from repro.train.schedule import warmup_cosine


def test_data_is_a_function_of_seed_and_step():
    cfg = DataConfig(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    b1, b2 = batch_at(cfg, 3), batch_at(cfg, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(cfg, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    b4 = batch_at(DataConfig(100, 16, 4, seed=8), 3)
    assert not np.array_equal(b1["tokens"], b4["tokens"])


def test_iterator_restore_reproduces_stream():
    cfg = DataConfig(vocab_size=50, seq_len=8, batch_size=2)
    it = SyntheticIterator(cfg)
    first = [next(it)["tokens"] for _ in range(5)]
    state = it.state()
    later = [next(it)["tokens"] for _ in range(3)]
    it2 = SyntheticIterator(cfg)
    it2.restore(state)
    again = [next(it2)["tokens"] for _ in range(3)]
    for a, b in zip(later, again, strict=True):
        np.testing.assert_array_equal(a, b)


def test_data_codebooks_and_vlm_fields():
    cfg = DataConfig(vocab_size=64, seq_len=8, batch_size=2,
                     num_codebooks=4)
    assert batch_at(cfg, 0)["tokens"].shape == (2, 8, 4)
    cfg2 = DataConfig(vocab_size=64, seq_len=8, batch_size=2,
                      num_image_tokens=3, d_model=16)
    b = batch_at(cfg2, 0)
    assert b["image_embeds"].shape == (2, 3, 16)
    assert b["image_positions"].shape == (2, 3)


@pytest.mark.parametrize("opt", [AdamW(weight_decay=0.0), Adafactor()])
def test_optimizer_minimises_quadratic(opt):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params, lr=0.05)
    assert float(loss_fn(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(20.0)


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(55)) < float(lr(20))


def test_error_feedback_compression_preserves_signal():
    """int8 fake-quant with error feedback: the accumulated applied update
    converges to the accumulated true gradient (residual stays bounded)."""
    from repro.sharding.compression import make_error_feedback_compress

    init, transform = make_error_feedback_compress(None)
    g = {"w": jnp.array([0.001, -1.0, 0.5, 3.0])}
    residual = init(g)
    applied = jnp.zeros(4)
    for _ in range(50):
        cg, residual = transform(g, residual)
        applied = applied + cg["w"]
    # mean applied update ~ true gradient
    np.testing.assert_allclose(np.asarray(applied) / 50,
                               np.asarray(g["w"]), atol=2e-2)
    # residual bounded by one quantisation step's worth
    assert float(jnp.max(jnp.abs(residual["w"]))) < 0.05


def test_int8_allreduce_matches_mean_subprocess():
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.sharding.compression import allreduce_int8

        mesh = jax.make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

        f = shard_map(lambda s: allreduce_int8(s, "data"), mesh=mesh,
                      in_specs=P("data", None), out_specs=P("data", None))
        out = f(x)
        want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
        err = float(jnp.max(jnp.abs(out - want)))
        assert err < 0.05, err
        print("ALLREDUCE_OK", err)
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=240)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "ALLREDUCE_OK" in r.stdout
