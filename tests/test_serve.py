"""Serving engine: continuous batching isolation, sampling, drain."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import lm
from repro.models.params import init_params
from repro.serve.engine import DecodeEngine, Request
from repro.serve.sampler import sample, sample_batch
import jax.numpy as jnp


def _engine(arch, slots=2, max_seq=64, **kw):
    cfg = reduced_config(arch)
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    return cfg, DecodeEngine(cfg, params, batch_slots=slots,
                             max_seq=max_seq, **kw)


def _params(cfg):
    return init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))


def test_sampler_greedy_and_topk():
    logits = jnp.array([0.1, 5.0, -1.0, 2.0])
    assert int(sample(logits, jax.random.PRNGKey(0))) == 1
    t = sample(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=2)
    assert int(t) in (1, 3)


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-130m"])
def test_continuous_batching_isolation(arch):
    """A request's greedy output must be identical whether it runs alone or
    alongside other requests in different slots (SSM state gating)."""
    cfg, eng1 = _engine(arch, slots=1)
    r_alone = Request(prompt=np.arange(5, dtype=np.int32) + 1,
                      max_new_tokens=6)
    eng1.submit(r_alone)
    eng1.run_until_drained()

    cfg, eng2 = _engine(arch, slots=3)
    r_same = Request(prompt=np.arange(5, dtype=np.int32) + 1,
                     max_new_tokens=6)
    other1 = Request(prompt=np.arange(9, dtype=np.int32) + 7,
                     max_new_tokens=9)
    other2 = Request(prompt=np.arange(3, dtype=np.int32) + 40,
                     max_new_tokens=4)
    eng2.submit(other1)
    eng2.submit(r_same)
    eng2.submit(other2)
    eng2.run_until_drained()
    assert [int(t) for t in r_alone.output] == \
        [int(t) for t in r_same.output]


def test_more_requests_than_slots_all_complete():
    cfg, eng = _engine("smollm-360m", slots=2)
    reqs = [Request(prompt=np.array([i + 1, i + 2], np.int32),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done and len(r.output) == 3 for r in reqs)


def test_sample_batch_greedy_and_tiebreak():
    logits = jnp.array([[0.1, 5.0, -1.0, 2.0],
                        [1.0, 5.0, 5.0, 0.0]])     # row 1: exact tie
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(2))
    toks = sample_batch(logits, keys, jnp.zeros(2), jnp.zeros(2, jnp.int32))
    assert toks.tolist() == [1, 1], \
        "greedy must pick argmax, ties broken by lowest index"


def test_sample_batch_per_slot_topk():
    logits = jnp.tile(jnp.array([0.0, 4.0, 3.0, 2.0]), (2, 1))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(2) + 9)
    toks = sample_batch(logits, keys, jnp.full(2, 5.0),
                        jnp.array([1, 2], jnp.int32))
    assert int(toks[0]) == 1                       # top-1 == forced argmax
    assert int(toks[1]) in (1, 2)                  # top-2 restricted support


def test_sample_batch_independent_streams():
    """Two slots with *identical* logits and temperature > 0 must draw from
    independent per-slot RNG streams (regression: the seed engine shared one
    key across slots, so identical logits always produced identical draws)."""
    logits = jnp.zeros((2, 64))                    # flat: draw is pure noise
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(2))
    draws = np.stack([
        np.asarray(sample_batch(
            logits, jax.vmap(jax.random.fold_in, (0, None))(keys, i),
            jnp.ones(2), jnp.zeros(2, jnp.int32)))
        for i in range(8)])
    assert not np.array_equal(draws[:, 0], draws[:, 1]), \
        "slots sharing RNG: identical logits produced identical draws"


def test_engine_rng_independent_across_slots():
    """Two temperature>0 requests with the same prompt running concurrently
    must not emit identical token streams."""
    cfg, eng = _engine("smollm-360m", slots=2)
    prompt = np.arange(4, dtype=np.int32) + 1
    a = Request(prompt=prompt, max_new_tokens=12, temperature=1.0)
    b = Request(prompt=prompt.copy(), max_new_tokens=12, temperature=1.0)
    eng.submit(a)
    eng.submit(b)
    eng.run_until_drained()
    assert [int(t) for t in a.output] != [int(t) for t in b.output]


@pytest.mark.parametrize("mode", ["fused", "host"])
def test_staggered_interleave_matches_solo(mode):
    """K requests with staggered admissions/retirements (more requests than
    slots, mixed lengths) decode greedily to exactly what each produces run
    alone, sequentially, through the same engine geometry."""
    cfg = reduced_config("smollm-360m")
    params = _params(cfg)
    rng = np.random.default_rng(5)
    work = [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(2, 7))).astype(np.int32),
             int(rng.integers(2, 9))) for _ in range(5)]
    kw = dict(batch_slots=2, max_seq=64, mode=mode, steps_per_sync=4)

    eng = DecodeEngine(cfg, params, **kw)
    reqs = [Request(prompt=p, max_new_tokens=m) for p, m in work]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    batched = [[int(t) for t in r.output] for r in reqs]

    for (p, m), got in zip(work, batched):
        solo_eng = DecodeEngine(cfg, params, **kw)
        solo = Request(prompt=p, max_new_tokens=m)
        solo_eng.submit(solo)
        solo_eng.run_until_drained()
        assert got == [int(t) for t in solo.output]


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen3-4b"])
def test_chunked_prefill_identity(arch):
    """Chunked prefill admission must reproduce sequential one-token-per-step
    prompt forcing byte-for-byte (attention archs: cache scatter is exact;
    SSD-scan archs recombine chunks in fp and are covered by tolerance tests
    in test_models)."""
    cfg = reduced_config(arch)
    params = _params(cfg)
    rng = np.random.default_rng(2)
    work = [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(9, 20))).astype(np.int32), 4)
            for _ in range(3)]

    def run(**extra):
        eng = DecodeEngine(cfg, params, batch_slots=2, max_seq=64,
                           steps_per_sync=4, **extra)
        reqs = [Request(prompt=p, max_new_tokens=m) for p, m in work]
        for r in reqs:
            eng.submit(r)
        steps = eng.run_until_drained()
        return [[int(t) for t in r.output] for r in reqs], steps

    seq, seq_steps = run()
    chunked, chunked_steps = run(prefill_chunk=4,
                                 max_prefill_tokens_per_sync=8)
    assert seq == chunked
    assert chunked_steps < seq_steps


def test_host_mode_drains_and_matches_lengths():
    cfg, eng = _engine("smollm-360m", slots=2, mode="host")
    reqs = [Request(prompt=np.array([i + 1, i + 2], np.int32),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done and len(r.output) == 3 for r in reqs)


# ---------------------------------------------------------------------------
# paged KV layout
# ---------------------------------------------------------------------------
def _run_mix(cfg, params, work, **kw):
    eng = DecodeEngine(cfg, params, **kw)
    reqs = [Request(prompt=p, max_new_tokens=m) for p, m in work]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return [[int(t) for t in r.output] for r in reqs], reqs, eng


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v3-671b",
                                  "jamba-v0.1-52b"])
def test_paged_matches_dense_greedy(arch):
    """Paged KV must reproduce dense greedy token-for-token across plain
    GQA, MLA, and hybrid attention/SSM stacks, with ragged lengths."""
    cfg = reduced_config(arch)
    params = _params(cfg)
    rng = np.random.default_rng(3)
    work = [(rng.integers(1, cfg.vocab_size,
                          int(rng.integers(2, 14))).astype(np.int32),
             int(rng.integers(3, 8))) for _ in range(5)]
    kw = dict(batch_slots=3, max_seq=40, steps_per_sync=4)
    dense, _, _ = _run_mix(cfg, params, work, **kw)
    paged, reqs, eng = _run_mix(cfg, params, work, kv_layout="paged",
                                page_size=8, **kw)
    assert dense == paged
    assert all(r.done and not r.failed for r in reqs)
    assert eng.pool.used_pages == 0          # all pages returned on drain


def test_paged_non_dividing_page_size():
    """page_size that divides neither max_seq nor typical lengths: the
    partial last page must mask correctly end to end."""
    cfg = reduced_config("smollm-360m")
    params = _params(cfg)
    rng = np.random.default_rng(4)
    work = [(rng.integers(1, cfg.vocab_size,
                          int(rng.integers(2, 20))).astype(np.int32), 6)
            for _ in range(4)]
    kw = dict(batch_slots=2, max_seq=60, steps_per_sync=4)
    dense, _, _ = _run_mix(cfg, params, work, **kw)
    paged, _, _ = _run_mix(cfg, params, work, kv_layout="paged",
                           page_size=7, **kw)
    assert dense == paged


def test_paged_prefill_chunk_matches_dense_chunked():
    cfg = reduced_config("smollm-360m")
    params = _params(cfg)
    rng = np.random.default_rng(6)
    work = [(rng.integers(1, cfg.vocab_size,
                          int(rng.integers(9, 20))).astype(np.int32), 4)
            for _ in range(3)]
    kw = dict(batch_slots=2, max_seq=64, steps_per_sync=4, prefill_chunk=4)
    dense, _, _ = _run_mix(cfg, params, work, **kw)
    paged, _, _ = _run_mix(cfg, params, work, kv_layout="paged",
                           page_size=8, **kw)
    assert dense == paged


def test_paged_pool_exhaustion_preempts_and_completes():
    """A pool far too small for the offered load must preempt (youngest
    first) yet still complete every request exactly once, with outputs
    identical to dense — at-least-once requeue, no deadlock, no loss."""
    cfg = reduced_config("smollm-360m")
    params = _params(cfg)
    rng = np.random.default_rng(7)
    work = [(rng.integers(1, cfg.vocab_size,
                          int(rng.integers(6, 14))).astype(np.int32), 12)
            for _ in range(8)]
    kw = dict(batch_slots=4, max_seq=40, steps_per_sync=4)
    dense, _, _ = _run_mix(cfg, params, work, **kw)
    # width = ceil(40/8) = 5; 6 pages can't back two long slots at once
    paged, reqs, eng = _run_mix(cfg, params, work, kv_layout="paged",
                                page_size=8, num_pages=6, **kw)
    assert eng.stats["preemptions"] >= 1
    assert all(r.done and not r.failed for r in reqs)
    assert [len(o) for o in paged] == [m for _, m in work]  # exactly once
    assert dense == paged


def test_paged_rejects_bad_prompts_and_keeps_serving():
    """Regression: malformed prompts used to assert-crash the engine.  Now
    they fail typed and everyone else is served."""
    cfg = reduced_config("smollm-360m")
    params = _params(cfg)
    for layout in ({"kv_layout": "dense"},
                   {"kv_layout": "paged", "page_size": 8}):
        eng = DecodeEngine(cfg, params, batch_slots=2, max_seq=16, **layout)
        empty = Request(prompt=np.zeros((0,), np.int32))
        good = Request(prompt=np.array([3, 4, 5], np.int32),
                       max_new_tokens=4)
        long = Request(prompt=np.ones((16,), np.int32))
        for r in (empty, good, long):
            eng.submit(r)
        eng.run_until_drained()
        assert empty.failed and "length 0" in empty.fail_reason
        assert long.failed and "length 16" in long.fail_reason
        assert good.done and not good.failed and len(good.output) == 4
        assert eng.stats["rejected"] == 2


def test_paged_admission_cost_independent_of_max_seq():
    """Satellite: dense admission round-trips the whole cache (scales with
    max_seq on stateful archs); paged touches only O(1) state + the pages
    actually allocated."""
    cfg = reduced_config("jamba-v0.1-52b")
    params = _params(cfg)
    work = [(np.arange(4, dtype=np.int32) + 1, 2) for _ in range(2)]

    def elems(max_seq, **kw):
        _, _, eng = _run_mix(cfg, params, work, batch_slots=2,
                             max_seq=max_seq, steps_per_sync=2, **kw)
        return eng.stats["admit_cache_elems"]

    d64, d128 = elems(64), elems(128)
    p64 = elems(64, kv_layout="paged", page_size=8)
    p128 = elems(128, kv_layout="paged", page_size=8)
    assert d128 > d64          # dense admission scales with max_seq
    assert p128 == p64         # paged admission does not
    assert p64 < d64


def test_paged_host_mode_matches_host_dense():
    cfg = reduced_config("smollm-360m")
    params = _params(cfg)
    rng = np.random.default_rng(8)
    work = [(rng.integers(1, cfg.vocab_size,
                          int(rng.integers(2, 10))).astype(np.int32), 5)
            for _ in range(4)]
    kw = dict(batch_slots=2, max_seq=40, mode="host")
    dense, _, _ = _run_mix(cfg, params, work, **kw)
    paged, _, _ = _run_mix(cfg, params, work, kv_layout="paged",
                           page_size=8, **kw)
    assert dense == paged


def test_musicgen_codebook_outputs():
    cfg, eng = _engine("musicgen-medium", slots=1)
    prompt = np.ones((3, cfg.num_codebooks), np.int32)
    r = Request(prompt=prompt, max_new_tokens=2)
    eng.submit(r)
    eng.run_until_drained()
    assert len(r.output) == 2
    assert r.output[0].shape == (cfg.num_codebooks,)
