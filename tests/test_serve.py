"""Serving engine: continuous batching isolation, sampling, drain."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import lm
from repro.models.params import init_params
from repro.serve.engine import DecodeEngine, Request
from repro.serve.sampler import sample
import jax.numpy as jnp


def _engine(arch, slots=2, max_seq=64):
    cfg = reduced_config(arch)
    params = init_params(lm.make_lm(cfg), jax.random.PRNGKey(0))
    return cfg, DecodeEngine(cfg, params, batch_slots=slots, max_seq=max_seq)


def test_sampler_greedy_and_topk():
    logits = jnp.array([0.1, 5.0, -1.0, 2.0])
    assert int(sample(logits, jax.random.PRNGKey(0))) == 1
    t = sample(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=2)
    assert int(t) in (1, 3)


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-130m"])
def test_continuous_batching_isolation(arch):
    """A request's greedy output must be identical whether it runs alone or
    alongside other requests in different slots (SSM state gating)."""
    cfg, eng1 = _engine(arch, slots=1)
    r_alone = Request(prompt=np.arange(5, dtype=np.int32) + 1,
                      max_new_tokens=6)
    eng1.submit(r_alone)
    eng1.run_until_drained()

    cfg, eng2 = _engine(arch, slots=3)
    r_same = Request(prompt=np.arange(5, dtype=np.int32) + 1,
                     max_new_tokens=6)
    other1 = Request(prompt=np.arange(9, dtype=np.int32) + 7,
                     max_new_tokens=9)
    other2 = Request(prompt=np.arange(3, dtype=np.int32) + 40,
                     max_new_tokens=4)
    eng2.submit(other1)
    eng2.submit(r_same)
    eng2.submit(other2)
    eng2.run_until_drained()
    assert [int(t) for t in r_alone.output] == \
        [int(t) for t in r_same.output]


def test_more_requests_than_slots_all_complete():
    cfg, eng = _engine("smollm-360m", slots=2)
    reqs = [Request(prompt=np.array([i + 1, i + 2], np.int32),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done and len(r.output) == 3 for r in reqs)


def test_musicgen_codebook_outputs():
    cfg, eng = _engine("musicgen-medium", slots=1)
    prompt = np.ones((3, cfg.num_codebooks), np.int32)
    r = Request(prompt=prompt, max_new_tokens=2)
    eng.submit(r)
    eng.run_until_drained()
    assert len(r.output) == 2
    assert r.output[0].shape == (cfg.num_codebooks,)
