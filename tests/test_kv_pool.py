"""KV page-pool allocator: free-list accounting, lazy growth, O(1) free."""
import pytest

from repro.serve.kv_pool import KVPool, PoolExhausted


def test_geometry_and_initial_state():
    pool = KVPool(num_pages=8, page_size=4, slots=2, max_seq=16)
    assert pool.width == 4
    assert pool.used_pages == 0
    assert pool.free_pages == 8
    assert (pool.table == 8).all()          # sentinel: nothing mapped


def test_width_rounds_up_for_non_dividing_page_size():
    pool = KVPool(num_pages=10, page_size=6, slots=1, max_seq=16)
    assert pool.width == 3                  # ceil(16/6)


def test_alloc_grows_lazily_and_is_idempotent():
    pool = KVPool(num_pages=8, page_size=4, slots=2, max_seq=16)
    fresh = pool.alloc(0, 5)                # rows 0..5 -> pages 0..1
    assert len(fresh) == 2
    assert pool.footprint(0) == 2
    assert pool.needed(0, 5) == 0
    assert pool.alloc(0, 5) == []           # already backed
    fresh = pool.alloc(0, 6)                # crosses into page 2? no: 6//4=1
    assert fresh == []
    fresh = pool.alloc(0, 8)                # row 8 -> logical page 2
    assert len(fresh) == 1


def test_pages_for_and_can_admit():
    pool = KVPool(num_pages=4, page_size=4, slots=4, max_seq=16)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    assert pool.can_admit(16)
    pool.alloc(0, 11)                       # 3 pages
    assert pool.can_admit(4)
    assert not pool.can_admit(5)


def test_exhaustion_raises_and_rolls_back():
    pool = KVPool(num_pages=3, page_size=4, slots=2, max_seq=16)
    pool.alloc(0, 7)                        # 2 pages
    with pytest.raises(PoolExhausted):
        pool.alloc(1, 7)                    # needs 2, only 1 free
    # failed alloc must not leak partial pages
    assert pool.free_pages == 1
    assert pool.footprint(1) == 0
    assert pool.alloc(1, 3)                 # 1 page still works


def test_free_slot_returns_everything():
    pool = KVPool(num_pages=8, page_size=4, slots=2, max_seq=16)
    pool.alloc(0, 10)
    pool.alloc(1, 2)
    assert pool.used_pages == 4
    assert pool.free_slot(0) == 3
    assert pool.used_pages == 1
    assert (pool.table[0] == 8).all()       # table reset to sentinel
    assert pool.free_slot(0) == 0           # double-free is a no-op


def test_freed_pages_are_reused():
    pool = KVPool(num_pages=2, page_size=4, slots=2, max_seq=8)
    a = pool.alloc(0, 7)
    pool.free_slot(0)
    b = pool.alloc(1, 7)
    assert sorted(a) == sorted(b)


def test_stats_and_high_water():
    pool = KVPool(num_pages=8, page_size=4, slots=2, max_seq=16)
    pool.alloc(0, 11)
    pool.free_slot(0)
    pool.alloc(1, 3)
    s = pool.stats()
    assert s["high_water"] == 3
    assert s["used_pages"] == 1
    assert s["total_allocs"] == 4
    assert s["total_frees"] == 3
