"""Dry-run pipeline on a small host-device mesh (subprocess keeps this
process at 1 device): lower+compile succeeds, roofline record is coherent,
inapplicable cells are reported as such."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dryrun(args, devices="8", timeout=520):
    env = dict(os.environ, PYTHONPATH="src", REPRO_DRYRUN_DEVICES=devices)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, timeout=timeout, cwd=ROOT)


@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),
    ("mamba2-130m", "long_500k"),
    ("musicgen-medium", "decode_32k"),
])
def test_dryrun_cell_small_mesh(arch, shape, tmp_path):
    out = str(tmp_path / "cell.json")
    r = run_dryrun(["--arch", arch, "--shape", shape,
                    "--mesh-shape", "2", "4",
                    "--mesh-axes", "data", "model", "--json", out])
    assert r.returncode == 0, r.stdout[-2500:] + r.stderr[-2500:]
    with open(out) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    roof = rec["roofline"]
    assert roof["hlo_flops"] > 0
    assert roof["dominant"] in ("compute", "memory", "collective")
    assert rec["chips"] == 8
    assert "CompiledMemoryStats" in rec["memory_analysis"]


def test_dryrun_inapplicable_cell(tmp_path):
    out = str(tmp_path / "cell.json")
    r = run_dryrun(["--arch", "qwen3-4b", "--shape", "long_500k",
                    "--mesh-shape", "2", "4",
                    "--mesh-axes", "data", "model", "--json", out])
    assert r.returncode == 0
    with open(out) as f:
        rec = json.load(f)
    assert rec["status"] == "inapplicable"


def test_dryrun_multipod_axes_small(tmp_path):
    """3-axis (pod, data, model) mesh shards on a small host config."""
    out = str(tmp_path / "cell.json")
    r = run_dryrun(["--arch", "smollm-360m", "--shape", "decode_32k",
                    "--mesh-shape", "2", "2", "2",
                    "--mesh-axes", "pod", "data", "model", "--json", out])
    assert r.returncode == 0, r.stdout[-2500:] + r.stderr[-2500:]
    with open(out) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["mesh"] == "pod2xdata2xmodel2"
