"""Hypothesis property tests over random workloads: scheduler invariants
hold for arbitrary hardness lattices / durations / deadlines / failures."""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_shard import NaiveMinHardSet  # noqa: E402

from repro.core.hardness import Hardness, MinHardSet
from repro.core.server import ServerConfig
from repro.core.sim import ShardedSimCluster, SimCluster, SimParams, SimTask

task_strategy = st.tuples(
    st.integers(0, 4),                    # hardness a
    st.integers(0, 4),                    # hardness b
    st.floats(0.1, 3.0),                  # duration
)


@given(st.lists(task_strategy, min_size=1, max_size=25),
       st.floats(0.5, 2.5),               # deadline
       st.integers(1, 3))                 # clients
@settings(max_examples=25, deadline=None)
def test_scheduler_invariants(specs, deadline, max_clients):
    tasks = [SimTask((a, b, i), ("a", "b", "id"), (a, b), dur, deadline,
                     (i,))
             for i, (a, b, dur) in enumerate(specs)]
    cl = SimCluster(tasks, ServerConfig(max_clients=max_clients,
                                        use_backup=False),
                    SimParams(client_workers=2))
    srv = cl.run(until=5000)
    table = srv.final_results

    # 1. every task reaches a terminal state
    assert all(s in ("done", "timed_out", "pruned") for _, _, s in table.rows)
    # 2. no solved task is disqualified by min_hard
    for p, r, s in table.rows:
        h = Hardness((p[0], p[1]))
        if s == "done":
            assert r is not None
    # 3. every pruned task dominates some timed-out hardness
    timed_out = [Hardness((p[0], p[1])) for p, r, s in table.rows
                 if s == "timed_out"]
    for p, _r, s in table.rows:
        if s == "pruned":
            h = Hardness((p[0], p[1]))
            assert any(h.geq(t) for t in timed_out), (p, s)
    # 4. results preserved 1:1 (no duplicates, no losses)
    done_ids = [p[2] for p, r, s in table.rows if s == "done"]
    assert len(done_ids) == len(set(done_ids)) == len(srv.results)


@given(st.lists(task_strategy, min_size=4, max_size=20),
       st.floats(3.0, 10.0),              # when to kill a client
       st.integers(2, 3))
@settings(max_examples=15, deadline=None)
def test_invariants_hold_under_client_failure(specs, kill_at, max_clients):
    tasks = [SimTask((a, b, i), ("a", "b", "id"), (a, b), dur, None, (i,))
             for i, (a, b, dur) in enumerate(specs)]
    cl = SimCluster(tasks, ServerConfig(max_clients=max_clients,
                                        use_backup=False,
                                        health_update_limit=3.0),
                    SimParams(client_workers=2))

    def kill(c):
        for name in c.engine.nodes:
            if name.startswith("client") and c.engine.alive.get(name):
                c.engine.kill(name)
                return

    cl.at(kill_at, kill)
    srv = cl.run(until=5000)
    # no deadline -> every task must eventually be solved despite the crash
    assert all(s == "done" for _, _, s in srv.final_results.rows)
    assert len(srv.results) == len(tasks)


hardness_strategy = st.tuples(st.integers(0, 6), st.integers(0, 6),
                              st.integers(0, 6))


@given(st.lists(hardness_strategy, min_size=1, max_size=120),
       st.lists(hardness_strategy, min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_indexed_minhardset_equals_naive_reference(adds, probes):
    indexed, naive = MinHardSet(), NaiveMinHardSet()
    for hv in adds:
        h = Hardness(hv)
        assert indexed.add(h) == naive.add(h), hv
    assert indexed.snapshot() == naive.snapshot()
    for hv in probes:
        h = Hardness(hv)
        assert indexed.disqualifies(h) == naive.disqualifies(h), hv
    # snapshot -> restore preserves both the frontier order and answers
    restored = MinHardSet()
    restored.restore(indexed.snapshot())
    assert restored.snapshot() == indexed.snapshot()
    for hv in probes:
        h = Hardness(hv)
        assert restored.disqualifies(h) == naive.disqualifies(h), hv


@given(st.integers(2, 6),                  # grid side a
       st.integers(2, 6),                  # grid side b
       st.floats(0.15, 0.4),               # per-unit duration
       st.floats(0.5, 3.0),                # deadline
       st.integers(2, 4))                  # shards
@settings(max_examples=10, deadline=None)
def test_sharded_pruning_equals_single_scheduler(na, nb, base, deadline,
                                                 n_shards):
    # durations monotone in hardness: the solved set is exactly
    # {dur <= deadline} for any shard count, so K shards with gossiped
    # frontiers must match the single scheduler set-for-set
    def grid():
        return [SimTask((a, b), ("a", "b"), (a, b), base * (a + b + 1),
                        deadline, (a * b,))
                for a in range(na) for b in range(nb)]

    single = SimCluster(grid(), ServerConfig(max_clients=3,
                                             use_backup=False),
                        SimParams(), _internal=True)
    t1 = single.run(until=4000).final_results
    sharded = ShardedSimCluster(grid(),
                                ServerConfig(max_clients=2,
                                             use_backup=False),
                                SimParams(), n_shards=n_shards,
                                _internal=True)
    sharded.run(until=4000)
    tk = sharded.merged_results()

    def sets(table):
        solved = {p for p, r, s in table.rows if s == "done"}
        gone = {p for p, r, s in table.rows
                if s in ("pruned", "timed_out")}
        return solved, gone

    assert sets(tk) == sets(t1)
    params = [p for p, _, _ in tk.rows]
    assert len(params) == len(set(params)) == na * nb
