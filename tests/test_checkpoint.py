"""Checkpointer: atomic save/restore with bf16, async writes, pruning,
restore-onto-different-sharding (elastic restart)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
                   "c": jnp.zeros((), jnp.int32)},
        "lst": [jnp.full((2,), 7, jnp.int8)],
    }


def test_roundtrip_including_bf16(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 5, t)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    restored, step, meta = ck.restore(str(tmp_path), like)
    assert step == 5
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32),
                                                   np.asarray(b, np.float32)),
        t, restored)
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_async_write_and_prune(tmp_path):
    t = tree()
    for s in (1, 2, 3, 4):
        w = ck.save(str(tmp_path), s, t, async_write=True)
        w.join()
    ck.prune(str(tmp_path), keep=2)
    assert ck.available_steps(str(tmp_path)) == [3, 4]


def test_restore_latest_by_default(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 9, jax.tree_util.tree_map(lambda x: x + 1, t))
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    _, step, _ = ck.restore(str(tmp_path), like)
    assert step == 9


RESHARD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import checkpointer as ck

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    # save from a 4-way model sharding
    mesh1 = jax.make_mesh((4,), ("model",))
    sh1 = {"w": NamedSharding(mesh1, P("model", None))}
    t1 = jax.tree_util.tree_map(jax.device_put, tree, sh1)
    ck.save("@DIR@", 1, t1)

    # restore onto a DIFFERENT mesh (2x2) and sharding (elastic restart)
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    restored, step, _ = ck.restore("@DIR@", like, shardings=sh2)
    assert restored["w"].sharding == sh2["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    print("RESHARD_OK")
""")


def test_elastic_reshard_restore_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", RESHARD.replace("@DIR@", str(tmp_path))],
        capture_output=True, text=True, env=env, timeout=240,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "RESHARD_OK" in r.stdout


def test_train_loop_resumes_after_injected_failure(tmp_path):
    from repro.configs import reduced_config
    from repro.data.synthetic import data_config_for
    from repro.train.loop import TrainJob, run_training

    cfg = reduced_config("smollm-360m")
    dc = data_config_for(cfg, seq_len=32, batch_size=2)
    job = TrainJob(total_steps=20, ckpt_every=5, ckpt_dir=str(tmp_path),
                   log_every=5, warmup=2, fail_after_step=11,
                   async_ckpt=False)
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(cfg, dc, job, log=lambda *a: None)
    assert max(ck.available_steps(str(tmp_path))) >= 10
    # restart (same arguments, as the ExpoCloud worker would re-run it)
    job2 = TrainJob(total_steps=20, ckpt_every=5, ckpt_dir=str(tmp_path),
                    log_every=5, warmup=2, async_ckpt=False)
    hist, final, _ = run_training(cfg, dc, job2, log=lambda *a: None)
    assert final == 20
    assert ck.available_steps(str(tmp_path))[-1] == 20
