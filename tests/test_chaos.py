"""Chaos-layer tests: simulated network partitions (per-link,
per-direction), partition-hardened liveness (grace, regrant, resync,
control-plane broadcast seq) and trace record/replay determinism."""
import pickle

import pytest

from repro.core.messages import Message, MsgType
from repro.core.scheduler import (DONE, LinkHealed, LinkLost, SchedulerCore,
                                  Tick)
from repro.core.server import ServerConfig
from repro.core.sim import SimCluster, SimParams, SimTask
from repro.core.trace import Trace


def mk_tasks(n, dur=1.0):
    return [SimTask((i, 0), ("n", "id"), (i,), dur, None, (i,))
            for i in range(1, n + 1)]


def solved_set(srv):
    return sorted(p[0] for p, r, s in srv.final_results.rows
                  if r is not None)


def client_events(srv, kind):
    out = []
    for cname in list(srv.core.events._events):
        for e in srv.core.events.for_client(cname):
            if isinstance(e.get("body"), dict) \
                    and e["body"].get("event") == kind:
                out.append((cname, e))
    return out


# ---------------------------------------------------------------------------
# transport-level partition semantics
# ---------------------------------------------------------------------------
def test_dark_route_drops_silently_and_autoheals():
    from repro.core.transport import SimNetwork, sim_link

    class Clk:
        t = 0.0

        def now(self):
            return self.t

    clk = Clk()
    net = SimNetwork(clk)
    a, b = sim_link(clk, latency=0.0, label_a="x", label_b="y", network=net)
    net.partition("x", "y", until=5.0)
    a.send("lost")                      # x->y dark: dropped, not deferred
    b.send("ok")                        # y->x unaffected (one-way)
    assert a.poll() == "ok" and b.poll() is None
    clk.t = 5.0
    assert not net.link_down("x", "y")  # lazy auto-heal at `until`
    a.send("after")
    assert b.poll() == "after"


def test_one_way_primary_to_client_loss_zero_lost_tasks():
    """Grants die on the dark server->client direction; the client keeps
    heartbeating and is never declared dead; request-retry + regrant
    recover every stranded assignment after the heal."""
    cl = SimCluster(
        mk_tasks(16, dur=1.0),
        ServerConfig(max_clients=2, use_backup=False,
                     health_update_limit=4.0, partition_grace_s=6.0),
        SimParams(client_workers=2))
    cl.partition("primary", "client-0", direction="a2b", at=3.0, until=15.0)
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 17))
    # the partitioned-but-heartbeating client was never dropped
    assert not client_events(srv, "unhealthy")


def test_one_way_client_to_server_loss_grace_keeps_client():
    """Client->server silence behind a *reported* partition gets
    partition_grace_s before the drop; healing within the grace means no
    reassignment churn at all."""
    cl = SimCluster(
        mk_tasks(12, dur=1.0),
        ServerConfig(max_clients=2, use_backup=False,
                     health_update_limit=3.0, partition_grace_s=8.0),
        SimParams(client_workers=2))
    # dark for 6s: beyond the health limit, within limit + grace
    cl.partition("client-0", "primary", direction="a2b", at=3.0, until=9.0)
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 13))
    assert not client_events(srv, "unhealthy")
    assert client_events(srv, "link_lost")      # the suspicion was raised
    assert client_events(srv, "link_healed")    # ... and cleared


def test_partition_beyond_grace_reassigns_exactly_once():
    """A partition outlasting limit + grace is a death: tasks are requeued
    and each RESULT lands exactly once."""
    n = 14
    cl = SimCluster(
        mk_tasks(n, dur=1.5),
        ServerConfig(max_clients=2, use_backup=False,
                     health_update_limit=3.0, partition_grace_s=2.0),
        SimParams(client_workers=2))
    cl.partition("client-0", "primary", at=4.0)     # never heals
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, n + 1))
    assert len(srv.results) == n                    # no double-counted RESULT
    assert client_events(srv, "unhealthy")          # the drop did happen


def test_late_result_after_heal_is_not_double_counted():
    """Core invariant behind 'heal never double-counts': a RESULT arriving
    for a task that is already DONE (reassigned + solved elsewhere while
    the original client was partitioned) must not corrupt the table."""
    cfg = ServerConfig(max_clients=4)
    core = SchedulerCore(mk_tasks(3), cfg)
    core.client_joined("a", 0.0)
    core.client_joined("b", 0.0)
    core.on_message(Message(MsgType.REQUEST_TASKS, "a", {"n": 1}), 0.0)
    tid = next(iter(core.clients["a"].assigned))
    # a partitions; its task is requeued and solved by b
    core.drop_client("a", 5.0, reassign=True)
    core.on_message(Message(MsgType.REQUEST_TASKS, "b", {"n": 1}), 6.0)
    core.on_message(Message(MsgType.RESULT, "b",
                            {"tid": tid, "result": (1,)}), 7.0)
    assert core.status[tid] == DONE
    # link heals: the zombie client's stale RESULT arrives
    core.client_joined("a", 8.0)
    core.on_message(Message(MsgType.RESULT, "a",
                            {"tid": tid, "result": (999,)}), 8.0)
    assert core.results[tid] == (1,)
    assert core.status[tid] == DONE


# ---------------------------------------------------------------------------
# control-plane broadcast seq (srv_seq divergence regression)
# ---------------------------------------------------------------------------
def test_broadcast_does_not_consume_srv_seq():
    core = SchedulerCore(mk_tasks(4), ServerConfig(max_clients=4))
    core.client_joined("a", 0.0)
    before = core.clients["a"].srv_seq
    effs = core.control_broadcast(MsgType.STOP)
    assert core.clients["a"].srv_seq == before
    assert effs[0].srv_seq is None and effs[0].ctrl_seq == 0
    assert core.ctrl_seq == 1


def test_primary_backup_srv_seq_agree_after_freeze_broadcast():
    """Regression (ROADMAP protocol item): STOP/RESUME broadcasts used to
    consume per-client srv_seq numbers the backup never mirrored, so the
    mirror lagged after every freeze; with the control-plane seq the two
    cores must agree on every client's srv_seq once the backup is live."""
    cl = SimCluster(mk_tasks(30, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0),
                    SimParams(client_workers=2))
    # run until the backup exists and has mirrored for a while
    for _ in range(100_000):
        cl.step()
        backups = [s for s in cl.servers() if s.role == "backup"]
        if backups and cl.clock.now() >= 12.0:
            break
    backups = [s for s in cl.servers() if s.role == "backup"]
    assert backups, "backup never came up"
    backup = backups[0]
    prim = cl.acting_primary()
    # freeze -> STOP -> RESUME happened at least once (backup creation);
    # every mirrored client must agree on srv_seq and ctrl_seq
    assert prim.core.ctrl_seq >= 1
    for cname, ci in backup.core.clients.items():
        assert prim.core.clients[cname].srv_seq == ci.srv_seq, cname
    assert prim.core.ctrl_seq == backup.core.ctrl_seq
    # ... and a takeover right now completes without deduped-send stalls
    cl.kill_primary()
    srv = cl.run(until=900)
    assert srv.name == "primary*"
    assert solved_set(srv) == list(range(1, 31))
    assert len(srv.results) == 30


def test_takeover_resumes_stopped_clients():
    """If the primary dies frozen (mid backup-replacement), the takeover
    RESUME releases clients stopped by the dying STOP broadcast."""
    cl = SimCluster(mk_tasks(24, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0),
                    SimParams(client_workers=2))

    def stop_then_die(c):
        prim = c.acting_primary()
        if prim is not None:
            prim._broadcast(MsgType.STOP, c.clock.now())
            c.kill_primary()

    cl.at(10.0, stop_then_die)
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 25))
    for client in cl.clients():
        assert not client.stopped or client.finished


# ---------------------------------------------------------------------------
# primary <-> backup partition: grace + resync instead of split-brain
# ---------------------------------------------------------------------------
def test_pb_partition_within_grace_no_takeover_and_resync():
    cl = SimCluster(mk_tasks(40, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0,
                                 partition_grace_s=10.0),
                    SimParams(client_workers=2))
    cl.partition("primary", "backup", at=8.0, until=14.0)
    srv = cl.run(until=900)
    # the acting primary at the end is still the original (no takeover)
    assert srv.name == "primary"
    assert solved_set(srv) == list(range(1, 41))
    # the backup noticed the gap and re-based on a fresh snapshot: its
    # mirror agrees with the primary on everything that was forwarded
    backups = [s for s in cl.servers() if s.role == "backup"]
    if backups:     # primary may have replaced it post-heal; if not, check
        b = backups[0]
        assert not b._resync_pending
        for tid, res in b.core.results.items():
            assert srv.core.results.get(tid) == res


def test_pb_partition_then_primary_death_takeover_completes():
    """The resynced mirror is good enough to take over from: partition the
    pb link mid-run (dropping FORWARDs), heal, then kill the primary —
    the backup must finish the experiment with every task solved once."""
    cl = SimCluster(mk_tasks(40, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0,
                                 partition_grace_s=10.0),
                    SimParams(client_workers=2))
    cl.partition("primary", "backup", at=8.0, until=14.0)
    cl.at(20.0, lambda c: c.kill_primary())
    srv = cl.run(until=900)
    assert srv.name == "primary*"
    assert solved_set(srv) == list(range(1, 41))
    assert len(srv.results) == 40


# ---------------------------------------------------------------------------
# snapshot -> restore -> replay determinism with partition events
# ---------------------------------------------------------------------------
def _canonical(snapshot) -> bytes:
    import json
    return json.dumps(snapshot, sort_keys=True,
                      default=lambda o: o.__dict__).encode()


@pytest.mark.parametrize("cut", [3, 7, 12])
def test_snapshot_replay_identical_with_link_events(cut):
    cfg = ServerConfig(max_clients=3, partition_grace_s=5.0,
                       health_update_limit=4.0)
    script = [
        ("client_joined", ("a", 0.0)), ("client_joined", ("b", 0.5)),
        ("on_message", (Message(MsgType.REQUEST_TASKS, "a", {"n": 2}), 1.0)),
        ("handle", (LinkLost("a", 2.0),)),
        ("on_tick", (Tick(2.5),)),
        ("on_message", (Message(MsgType.REQUEST_TASKS, "b", {"n": 1}), 3.0)),
        ("handle", (LinkLost("b", 3.5),)),
        ("on_tick", (Tick(4.0),)),
        ("handle", (LinkHealed("a", 5.0),)),
        ("on_message", (Message(MsgType.HEALTH_UPDATE, "a", None), 5.5)),
        ("on_tick", (Tick(6.0),)),
        ("handle", (LinkHealed("b", 7.0),)),
        ("on_tick", (Tick(9.5),)),
        ("on_tick", (Tick(12.0),)),
    ]

    def drive(core, part):
        for method, args in part:
            getattr(core, method)(*args)

    a = SchedulerCore(mk_tasks(8), cfg)
    drive(a, script)

    b = SchedulerCore(mk_tasks(8), cfg)
    drive(b, script[:cut])
    b2 = SchedulerCore.restore(pickle.loads(pickle.dumps(b.snapshot())))
    drive(b2, script[cut:])
    assert _canonical(a.snapshot()) == _canonical(b2.snapshot())


# ---------------------------------------------------------------------------
# trace record/replay
# ---------------------------------------------------------------------------
def _chaotic_cluster(params: SimParams):
    cl = SimCluster(
        mk_tasks(24, dur=1.5),
        ServerConfig(max_clients=3, use_backup=False,
                     health_update_limit=5.0),
        params)
    return cl


def test_trace_record_replay_reproduces_rows(tmp_path):
    rec = _chaotic_cluster(SimParams(client_workers=2, latency_jitter=0.04,
                                     seed=11, record_trace=True))
    rec.spot_wave(6.0, 0.34)
    srv = rec.run(until=900)
    rows = srv.final_results.rows
    path = str(tmp_path / "trace.json")
    rec.write_trace(path)

    # replay through the event engine: jitter/seed params deliberately
    # different — every delay, runtime and preemption comes from the trace
    rep = _chaotic_cluster(SimParams(client_workers=2, latency_jitter=0.0,
                                     seed=999, trace=path))
    srv2 = rep.run(until=900)
    assert srv2.final_results.rows == rows
    assert abs(rep.clock.now() - rec.clock.now()) < 1e-6


def test_trace_replay_with_partitions_in_stream(tmp_path):
    """Partition scripts are scenario (not timing): replaying a trace under
    the same partition script reproduces the run exactly."""
    def build(params):
        cl = _chaotic_cluster(params)
        cl.partition("primary", "client-0", at=3.0, until=9.0)
        return cl

    rec = build(SimParams(client_workers=2, latency_jitter=0.03, seed=5,
                          record_trace=True))
    srv = rec.run(until=900)
    trace = rec.trace()
    rep = build(SimParams(client_workers=2, seed=123, trace=trace))
    srv2 = rep.run(until=900)
    assert srv2.final_results.rows == srv.final_results.rows


def test_trace_from_run_builds_runtimes():
    from repro.core.trace import trace_from_run

    cl = _chaotic_cluster(SimParams(client_workers=2))
    srv = cl.run(until=900)
    trace = trace_from_run(srv.core.events.snapshot(),
                           cl.engine.billing_records())
    assert trace.task_runtimes            # started/done pairs reconstructed
    for dur in trace.task_runtimes.values():
        assert dur > 0
    # a real-run trace replays through the engine (runtimes only)
    rep = _chaotic_cluster(SimParams(client_workers=2, trace=trace))
    srv2 = rep.run(until=900)
    assert solved_set(srv2) == solved_set(srv)


def test_trace_json_roundtrip(tmp_path):
    t = Trace(message_delays={"a->b": [0.1, 0.2]},
              creation_delays={"client-0": 2.0},
              task_runtimes={"3": 1.5}, preemptions=[(4.0, "client-1")])
    p = str(tmp_path / "t.json")
    t.write(p)
    t2 = Trace.load(p)
    assert t2.message_delays == t.message_delays
    assert t2.creation_delays == t.creation_delays
    assert t2.task_runtimes == t.task_runtimes
    assert t2.preemptions == t.preemptions


# ---------------------------------------------------------------------------
# flapping links (the chaos-bench scenario, in miniature)
# ---------------------------------------------------------------------------
def test_flapping_links_all_tasks_complete():
    import random as _random

    cl = SimCluster(
        mk_tasks(24, dur=1.0),
        ServerConfig(max_clients=3, use_backup=False,
                     health_update_limit=6.0, partition_grace_s=8.0),
        SimParams(client_workers=2))
    rng = _random.Random(7)

    def flap(c):
        names = [cl_.name for cl_ in c.clients()
                 if c.engine.alive.get(cl_.name, False)]
        for name in names:
            if rng.random() < 0.2:
                direction = rng.choice(["a2b", "b2a", "both"])
                c.engine.partition("primary", name, direction,
                                   until=c.clock.now() + 1.0)
        if c.clock.now() < 20.0:
            c.at(c.clock.now() + 2.0, flap)

    cl.at(2.0, flap)
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 25))
    assert len(srv.results) == 24
