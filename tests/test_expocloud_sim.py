"""End-to-end scheduler behaviour on the deterministic simulator."""
import pytest

from repro.core.server import ServerConfig
from repro.core.sim import SimCluster, SimParams, SimTask


def mk_tasks(n, dur=1.0, deadline=None, hardness=None):
    return [SimTask((i, 0), ("n", "id"),
                    hardness(i) if hardness else (i,),
                    dur if isinstance(dur, float) else dur(i),
                    deadline, (i,))
            for i in range(1, n + 1)]


def test_all_tasks_solved_and_order_restored():
    tasks = mk_tasks(15)
    # shuffle: server must sort by hardness and restore original order
    tasks = tasks[::-1]
    cl = SimCluster(tasks, ServerConfig(max_clients=3, use_backup=False))
    srv = cl.run(until=600)
    rows = srv.final_results.rows
    assert [p[0] for p, r, s in rows] == [t.parameters()[0] for t in tasks]
    assert all(r is not None for _, r, _ in rows)


def test_timeout_triggers_domino_pruning():
    # duration grows with i; deadline cuts at i == 7
    tasks = mk_tasks(12, dur=lambda i: 0.6 * i, deadline=4.0)
    cl = SimCluster(tasks, ServerConfig(max_clients=2, use_backup=False))
    srv = cl.run(until=600)
    status = {p[0]: s for p, r, s in srv.final_results.rows}
    solved = [i for i, s in status.items() if s == "done"]
    assert max(solved) <= 7
    assert "timed_out" in status.values()
    assert "pruned" in status.values()
    # min_hard retained the minimal timed-out hardness only
    assert len(srv.min_hard) == 1


def test_domino_prunes_only_dominating_tasks():
    """2-d hardness: timeout on (3, 0) must not prune (0, k) tasks."""
    tasks = []
    for a in range(5):
        for b in range(5):
            dur = 10.0 if (a >= 3 and b >= 3) else 0.2
            tasks.append(SimTask((a, b, 0), ("a", "b", "id"), (a, b),
                                 dur, 2.0, (a * b,)))
    cl = SimCluster(tasks, ServerConfig(max_clients=2, use_backup=False))
    srv = cl.run(until=600)
    for p, _r, s in srv.final_results.rows:
        a, b, _ = p
        if a < 3 or b < 3:
            assert s == "done", (p, s)
        else:
            assert s in ("timed_out", "pruned"), (p, s)


def test_min_group_size_retention():
    # group (n,) of 4 instances each; make instance-id 3 of group 2 time out
    tasks = []
    for n in (1, 2):
        for i in range(4):
            slow = (n == 2 and i == 3)
            tasks.append(SimTask(
                (n, i), ("n", "id"), (n, i), 5.0 if slow else 0.3,
                2.0 if slow else None, (n * 10 + i,)))
    cfg = ServerConfig(max_clients=1, use_backup=False, min_group_size=4)
    cl = SimCluster(tasks, cfg, SimParams(client_workers=1))
    srv = cl.run(until=600)
    rows = srv.final_results.rows
    kept_groups = {p[0] for p, r, s in rows}
    assert kept_groups == {1}, "group 2 has only 3 solved -> dropped"
    assert srv.final_results.dropped_groups == [(2,)]


def test_instances_deleted_when_done_saves_money():
    """BYE -> terminate: cost must be far below keeping clients to the end."""
    tasks = mk_tasks(8, dur=0.5)
    cl = SimCluster(tasks, ServerConfig(max_clients=4, use_backup=False))
    srv = cl.run(until=600)
    # let the BYE round-trips drain (the server keeps running after done)
    for _ in range(300):
        cl.step()
    # after completion no client instances remain (only the primary)
    assert cl.engine.list_instances() == ["primary"]


def test_easiest_first_assignment():
    """With one worker, tasks must complete in hardness order."""
    tasks = mk_tasks(6)[::-1]
    cl = SimCluster(tasks, ServerConfig(max_clients=1, use_backup=False),
                    SimParams(client_workers=1))
    srv = cl.run(until=600)
    events = srv.events
    done_order = []
    for client in ("client-0",):
        for e in events.for_client(client):
            if e["kind"] == "LOG" and e["body"].get("event") == "done":
                # clients batch lifecycle LOGs per wake ({"tids": [...]})
                done_order.extend(e["body"].get("tids")
                                  or [e["body"]["tid"]])
    assert done_order == sorted(done_order)
