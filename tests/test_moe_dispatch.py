"""MoE dispatch strategies: gather (SPMD baseline) vs ep (shard_map expert
parallelism) must agree; routing properties."""
import os
import subprocess
import sys

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.moe import _capacity, _route, apply_moe_gather, make_moe
from repro.models.params import init_params

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(scoring="softmax", cf=4.0):
    cfg = reduced_config("olmoe-1b-7b")
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, scoring=scoring, capacity_factor=cf))


def test_route_topk_weights_normalised_sigmoid():
    cfg = _cfg(scoring="sigmoid")
    p = init_params(make_moe(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model),
                          jnp.bfloat16)
    w, ids, aux = _route(cfg, p, x)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, 1)), 1.0, atol=1e-5)
    assert ids.shape == (32, cfg.moe.top_k)
    assert bool(jnp.isfinite(aux))


def test_gather_dispatch_handles_capacity_overflow():
    """With capacity_factor tiny, outputs stay finite and bounded."""
    cfg = _cfg(cf=0.1)
    p = init_params(make_moe(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model),
                          jnp.bfloat16)
    y, aux = apply_moe_gather(cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


def test_aux_loss_penalises_imbalance():
    cfg = _cfg()
    p = init_params(make_moe(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (128, cfg.d_model),
                          jnp.bfloat16)
    _, _, aux_balanced = _route(cfg, p, x)
    # collapse routing to expert 0
    p2 = dict(p)
    p2["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, _, aux_collapsed = _route(cfg, p2, x)
    assert float(aux_collapsed) > float(aux_balanced)


EP_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.models import lm
from repro.models.params import init_params, param_shardings
from repro.sharding.rules import make_rules, use_rules

cfg = reduced_config("olmoe-1b-7b")
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
descr = lm.make_lm(cfg)
params = init_params(descr, jax.random.PRNGKey(0))
tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": tok}
os.environ["REPRO_MOE"] = "gather"
ref, _ = jax.jit(lambda p, b: lm.train_loss(cfg, p, b, remat=False))(params, batch)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = make_rules(mesh)
psh = param_shardings(descr, rules)
ps = jax.tree_util.tree_map(jax.device_put, params, psh)
os.environ["REPRO_MOE"] = "ep"
def f(p, b):
    with use_rules(rules):
        return lm.train_loss(cfg, p, b, remat=False)
with mesh:
    loss, _ = jax.jit(f, in_shardings=(psh, None))(ps, batch)
assert abs(float(loss) - float(ref)) < 2e-2, (float(loss), float(ref))
print("EP_PARITY_OK")
"""


def test_ep_dispatch_parity_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", EP_PARITY],
                       capture_output=True, text=True, env=env,
                       timeout=480, cwd=ROOT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "EP_PARITY_OK" in r.stdout
