"""Discrete-event simulator core: protocol-bug regressions, fixed-vs-event
equivalence on seed scenarios, and the scenario knobs the old fixed-step
loop could not afford (heterogeneous instance types, spot-preemption waves,
latency jitter)."""
import pytest

from repro.core.hardness import Hardness
from repro.core.messages import Message, MsgType
from repro.core.server import (ASSIGNED, DONE, TIMED_OUT, Server,
                               ServerConfig)
from repro.core.sim import (InstanceType, SimCluster, SimParams, SimTask,
                            Clock)
from repro.core.workerpool import SimWorkerPool


def mk_tasks(n, dur=1.0, deadline=None):
    return [SimTask((i, 0), ("n", "id"), (i,), dur, deadline, (i,))
            for i in range(1, n + 1)]


def solved_set(srv):
    return sorted(p[0] for p, r, s in srv.final_results.rows
                  if r is not None)


# ---------------------------------------------------------------------------
# regression: partial GRANT_TASKS must settle the whole request
# ---------------------------------------------------------------------------
class _ListChan:
    def __init__(self):
        self.sent = []

    def send(self, msg):
        self.sent.append(msg)

    def poll(self):
        return None


def test_partial_grant_settles_outstanding():
    from repro.core.client import Client

    clock = Clock()
    pool = SimWorkerPool(4, clock)
    c = Client("c0", _ListChan(), None, pool, clock=clock.now)
    c.outstanding = 4      # as after REQUEST_TASKS {"n": 4}
    grant = [(0, SimTask((1, 0), ("n", "id"), (1,), 0.1, None, (1,))),
             (1, SimTask((2, 0), ("n", "id"), (2,), 0.1, None, (2,)))]
    c._act(Message(MsgType.GRANT_TASKS, "primary",
                   {"tasks": grant, "requested": 4}, srv_seq=0))
    # a 2-of-4 grant must clear all 4 outstanding, not leak 2 forever
    assert c.outstanding == 0


def test_straggler_client_regains_full_concurrency():
    """A client whose first request was partially granted must still use
    all its workers once failed tasks are reassigned to it."""
    cl = SimCluster(mk_tasks(5, dur=4.0),
                    ServerConfig(max_clients=2, use_backup=False,
                                 health_update_limit=3.0))

    def kill_c0(c):
        if c.engine.alive.get("client-0"):
            c.engine.kill("client-0")
    cl.at(4.0, kill_c0)

    srv = cl.run(until=900)
    assert solved_set(srv) == [1, 2, 3, 4, 5]
    # client-1's first request (4 workers) was granted only 1 task; after
    # client-0's 4 tasks are reassigned, client-1 must run them in
    # parallel (~4s), not serially (~16s).  Leaked `outstanding` made it
    # request one task at a time.
    assert cl.clock.now() < 16.0, cl.clock.now()


# ---------------------------------------------------------------------------
# regression: liveness must be keyed by engine registry name
# ---------------------------------------------------------------------------
def test_takeover_then_kill_reports_dead_primary():
    cl = SimCluster(mk_tasks(40, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0))
    cl.at(8.0, lambda c: c.kill_primary())
    srv = cl.run(until=900)
    assert srv.name == "primary*" and srv.role == "primary"
    # the acting primary is an engine node whose registry key != node.name
    key = next(k for k, v in cl.engine.nodes.items() if v is srv)
    assert key != srv.name
    assert cl.acting_primary() is srv
    assert srv in cl.servers()
    # kill the backup-turned-primary by its engine name: it must no longer
    # be reported alive (the old code looked up alive["primary*"] -> True)
    cl.engine.kill(key)
    assert cl.acting_primary() is None
    assert srv not in cl.servers()


# ---------------------------------------------------------------------------
# regression: late RESULT for a non-ASSIGNED task is ignored
# ---------------------------------------------------------------------------
class _StubEngine:
    def __init__(self):
        self.t = 0.0

    def now(self):
        return self.t


def test_late_result_after_timeout_is_ignored():
    tasks = mk_tasks(3, dur=1.0, deadline=2.0)
    srv = Server(tasks, _StubEngine(), ServerConfig(use_backup=False))
    from repro.core.server import ClientInfo
    srv.clients["c0"] = ClientInfo("c0", _ListChan(), 0.0)
    srv.process_client_message(
        Message(MsgType.REQUEST_TASKS, "c0", {"n": 2}))
    assert srv.status[0] == ASSIGNED and srv.status[1] == ASSIGNED
    # the harder task (tid 1) times out; tid 0 stays assigned
    srv.process_client_message(
        Message(MsgType.REPORT_HARD_TASK, "c0",
                {"tid": 1, "hardness": tasks[1].hardness().values}))
    assert srv.status[1] == TIMED_OUT
    # a racy late RESULT for the timed-out task must not flip it to DONE
    srv.process_client_message(
        Message(MsgType.RESULT, "c0", {"tid": 1, "result": (99,)}))
    assert srv.status[1] == TIMED_OUT
    assert 1 not in srv.results
    # ... while a RESULT for a still-ASSIGNED task is accepted as usual
    srv.process_client_message(
        Message(MsgType.RESULT, "c0", {"tid": 0, "result": (7,)}))
    assert srv.status[0] == DONE and srv.results[0] == (7,)


# ---------------------------------------------------------------------------
# fixed-vs-event equivalence on seed scenarios (identical ResultsTable)
# ---------------------------------------------------------------------------
def _both_modes(build):
    rows = {}
    for mode in ("fixed", "events"):
        cl, until = build(SimParams(client_workers=1, mode=mode))
        srv = cl.run(until=until)
        rows[mode] = srv.final_results.rows
    return rows["fixed"], rows["events"]


def test_equivalent_takeover_mid_grant():
    def build(params):
        params.client_workers = 4
        cl = SimCluster(mk_tasks(30, dur=2.0),
                        ServerConfig(max_clients=2, use_backup=True,
                                     health_update_limit=3.0), params)
        cl.at(8.0, lambda c: c.kill_primary())
        return cl, 900
    fixed, events = _both_modes(build)
    assert fixed == events
    assert all(s == "done" for _, _, s in events)


def test_equivalent_domino_prunes_queued_tasks():
    """Serial client: first hard task times out; every dominated task —
    including granted-but-not-yet-started (queued) ones — is pruned, in
    both engine modes, with identical tables."""
    def build(params):
        tasks = [SimTask((i, 0), ("n", "id"), (i,),
                         0.2 if i <= 4 else 50.0,
                         2.0, (i,))
                 for i in range(1, 9)]
        cl = SimCluster(tasks, ServerConfig(max_clients=1, use_backup=False),
                        params)
        return cl, 900
    fixed, events = _both_modes(build)
    assert fixed == events
    status = {p[0]: s for p, r, s in events}
    assert all(status[i] == "done" for i in range(1, 5))
    assert status[5] == "timed_out"
    assert all(status[i] == "pruned" for i in range(6, 9))


def test_equivalent_poison_task_cap():
    class AlwaysCrash(SimTask):
        def run(self):
            raise RuntimeError("poison")

    def build(params):
        tasks = [SimTask((1, 0), ("n", "id"), (1,), 0.3, None, (1,)),
                 AlwaysCrash((2, 0), ("n", "id"), (2,), 0.3, None, (2,)),
                 SimTask((3, 0), ("n", "id"), (3,), 0.3, None, (3,))]
        cl = SimCluster(tasks, ServerConfig(max_clients=1, use_backup=False,
                                            max_task_attempts=3), params)
        return cl, 900
    fixed, events = _both_modes(build)
    assert fixed == events
    status = {p[0]: s for p, r, s in events}
    assert status == {1: "done", 2: "pruned", 3: "done"}


# ---------------------------------------------------------------------------
# scenario diversity on the event core
# ---------------------------------------------------------------------------
def test_heterogeneous_instance_types():
    params = SimParams(instance_types={
        "client": InstanceType(creation_delay=0.2,
                               cost_per_instance_second=3.0,
                               client_workers=2),
    })
    cl = SimCluster(mk_tasks(6, dur=0.5),
                    ServerConfig(max_clients=2, use_backup=False), params)
    # step until the first client materializes so the worker-count
    # override is asserted on a live pool (after run() clients have BYE'd)
    for _ in range(2000):
        if cl.clients():
            break
        cl.step()
    assert cl.clients(), "no client materialized"
    assert all(c.pool.n_workers == 2 for c in cl.clients())
    srv = cl.run(until=600)
    assert solved_set(srv) == list(range(1, 7))
    # per-kind billing rate took effect
    assert any(rate == 3.0 for _, _, _, rate in cl.engine.cost_log)
    # fast boot: first client materialized well before the default 2s delay
    first_boot = min(t for name, t, _, _ in cl.engine.cost_log
                     if name.startswith("client"))
    assert first_boot < 1.0


def test_spot_preemption_wave_recovers():
    cl = SimCluster(mk_tasks(24, dur=2.0),
                    ServerConfig(max_clients=3, use_backup=False,
                                 health_update_limit=3.0),
                    SimParams(client_workers=2, seed=7))
    cl.spot_wave(6.0, 0.5)       # kill half the alive clients at t=6
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 25))
    # the wave actually killed someone (cost_log keeps terminated victims)
    assert any(not alive for name, alive in cl.engine.alive.items()
               if name.startswith("client")) or \
        any(name.startswith("client") for name, _, _, _ in cl.engine.cost_log)


def test_latency_jitter_is_seed_deterministic():
    def run(seed):
        cl = SimCluster(mk_tasks(12, dur=1.0),
                        ServerConfig(max_clients=2, use_backup=False),
                        SimParams(client_workers=2, latency_jitter=0.05,
                                  seed=seed))
        srv = cl.run(until=600)
        return srv.final_results.rows, cl.clock.now()
    rows_a, t_a = run(3)
    rows_b, t_b = run(3)
    assert rows_a == rows_b and t_a == t_b
    rows_c, _ = run(11)          # different seed still completes correctly
    assert [p for p, r, s in rows_c] == [p for p, r, s in rows_a]
    assert all(s == "done" for _, _, s in rows_c)


def test_event_engine_does_linear_work_in_events():
    """O(events) core: the event count for a no-failure run stays far below
    the fixed-step loop's step*node count for the same scenario."""
    cl = SimCluster(mk_tasks(20, dur=1.0),
                    ServerConfig(max_clients=2, use_backup=False),
                    SimParams(client_workers=4))
    cl.run(until=600)
    makespan = cl.clock.now()
    fixed_step_equivalent = (makespan / 0.05) * 3   # 3 nodes stepped per dt
    assert cl.loop.processed < fixed_step_equivalent / 3
