"""expolint: each rule catches its bad fixture, the live tree is clean,
suppressions work, and the CLI speaks JSON with the right exit codes."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import run_checks

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"

# (fixture dir, rule it must trip, fragment expected in some message)
CASES = [
    ("purity_bad", "core-purity", "wall-clock"),
    ("effects_bad", "effect-exhaustiveness", "isinstance branch"),
    ("snapshot_bad", "snapshot-completeness", "snapshot()"),
    ("seq_bad", "seq-discipline", "srv_seq"),
    ("pallas_bad", "pallas-rules", "divisibility"),
    ("pallas_paged_bad", "pallas-rules", "divisibility"),
    ("shard_bad", "snapshot-completeness", "snapshot()"),
    ("shard_bad", "core-purity", "wall-clock"),
]


def test_shard_bad_names_both_missing_fields():
    messages = " | ".join(
        v.message for v in run_checks(FIXTURES / "shard_bad",
                                      rules=["snapshot-completeness"]))
    assert "self.pending" in messages       # missing from snapshot()
    assert "self.last_pump_at" in messages  # missing from both sites


@pytest.mark.parametrize("case,rule,fragment", CASES)
def test_rule_catches_bad_fixture(case, rule, fragment):
    violations = run_checks(FIXTURES / case, rules=[rule])
    assert violations, f"{rule} found nothing in fixture {case}"
    assert all(v.rule == rule for v in violations)
    assert any(fragment in v.message for v in violations), \
        [v.message for v in violations]


def test_purity_catches_every_ban_family():
    messages = " | ".join(
        v.message for v in run_checks(FIXTURES / "purity_bad",
                                      rules=["core-purity"]))
    for fragment in ("time.", "os.environ", "random.", "open", "threading"):
        assert fragment in messages, (fragment, messages)


def test_effects_bad_finds_all_four_gaps():
    messages = " | ".join(
        v.message for v in run_checks(FIXTURES / "effects_bad",
                                      rules=["effect-exhaustiveness"]))
    assert "ClientLost" in messages      # event without handle branch
    assert "LaunchProbe" in messages     # effect without _apply branch
    assert "MsgType.PING" in messages    # produced, never consumed
    assert "MsgType.PONG" in messages    # consumed, never produced


def test_seq_bad_finds_all_three_shapes():
    messages = " | ".join(
        v.message for v in run_checks(FIXTURES / "seq_bad",
                                      rules=["seq-discipline"]))
    assert "STOP" in messages                       # control via _send
    assert "per-client" in messages                 # srv_seq fan-out
    assert "both srv_seq and ctrl_seq" in messages  # mixed planes


def test_live_tree_is_clean():
    assert run_checks(REPO) == []


def test_suppression_comments():
    assert run_checks(FIXTURES / "suppressed", rules=["core-purity"]) == []


def test_unknown_rule_rejected():
    with pytest.raises(ValueError, match="no-such-rule"):
        run_checks(FIXTURES / "purity_bad", rules=["no-such-rule"])


def test_cli_json_and_exit_codes():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "--root", str(FIXTURES / "purity_bad"), "--json"],
        capture_output=True, text=True, env=env)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    payload = json.loads(bad.stdout)
    assert payload["ok"] is False
    assert all({"rule", "path", "line", "message"} <= set(v)
               for v in payload["violations"])
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root", str(REPO)],
        capture_output=True, text=True, env=env)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    usage = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules", "typo"],
        capture_output=True, text=True, env=env)
    assert usage.returncode == 2
