"""Engine contract tests (GCE/TPU command construction against a fake
runner; LocalEngine end-to-end) + the paper's B&B example correctness."""
import sys
import time
import warnings

import numpy as np
import pytest

sys.path.insert(0, "examples")

from repro.core.engine import GCEEngine, TPUPodEngine, LocalEngine
from repro.core.server import Server, ServerConfig
from repro.core.sim import SimCluster, SimParams, SimTask


GCE_CONFIG = {
    "prefix": "agent-assignment",
    "project": "bnb-agent-assignment",
    "zone": "us-central1-a",
    "server_image": "server-template",
    "client_image": "client-template",
    "root_folder": "~/ExpoCloud",
    "project_folder": "examples.agent_assignment",
}


def test_gce_engine_command_contract():
    calls = []

    def fake_runner(cmd):
        calls.append(cmd)
        if cmd[2] == "instances" and cmd[3] == "list":
            return "agent-assignment-client-0\nagent-assignment-client-1\n"
        return ""

    eng = GCEEngine(GCE_CONFIG, runner=fake_runner)
    eng.create_instance("client", "client-0")
    eng.create_instance("backup", "backup-0")
    assert eng.list_instances() == ["client-0", "client-1"]
    eng.terminate_instance("client-0")
    create, backup_create, lst, delete = calls
    assert create[:4] == ["gcloud", "compute", "instances", "create"]
    assert "agent-assignment-client-0" in create
    assert "--source-machine-image=client-template" in create
    assert "--source-machine-image=server-template" in backup_create
    assert "--zone=us-central1-a" in create
    assert delete[3] == "delete" and "--quiet" in delete


def test_gce_engine_rejects_missing_keys():
    with pytest.raises(ValueError, match="missing keys"):
        GCEEngine({"prefix": "x"})


def test_tpu_pod_engine_uses_queued_resources():
    calls = []
    eng = TPUPodEngine(dict(GCE_CONFIG, accelerator_type="v5litepod-256"),
                       runner=lambda c: calls.append(c) or "")
    eng.create_instance("client", "pod-0")
    cmd = calls[0]
    assert cmd[2:5] == ["tpus", "queued-resources", "create"]
    assert "--accelerator-type=v5litepod-256" in cmd


def test_tpu_pod_engine_delete_and_list_commands():
    calls = []
    eng = TPUPodEngine(dict(GCE_CONFIG),
                       runner=lambda c: calls.append(c) or
                       "agent-assignment-pod-0\n")
    eng.create_instance("client", "pod-0")
    assert eng.list_instances() == ["pod-0"]
    eng.terminate_instance("pod-0")
    _create, lst, delete = calls
    assert lst[2:5] == ["tpus", "queued-resources", "list"]
    assert delete[2:5] == ["tpus", "queued-resources", "delete"]
    assert "--force" in delete and "--quiet" in delete
    assert "agent-assignment-pod-0" in delete
    # billing interval closed by the delete
    (rec,) = [r for r in eng.billing_records() if r[0] == "pod-0"]
    assert rec[4] is not None


def test_gce_cost_rate_per_kind_and_warn_once_fallback():
    eng = GCEEngine(dict(GCE_CONFIG,
                         cost_rates={"client": 2.5, "backup": 4.0}),
                    runner=lambda c: "")
    assert eng.cost_rate("client") == 2.5
    assert eng.cost_rate("backup") == 4.0
    with pytest.warns(UserWarning, match="cost_rates"):
        assert eng.cost_rate("gpu") == 1.0
    # warned once per kind: the second lookup is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert eng.cost_rate("gpu") == 1.0
    # scalar config applies to every kind, no warning
    eng2 = GCEEngine(dict(GCE_CONFIG, cost_rates=0.5), runner=lambda c: "")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert eng2.cost_rate("anything") == 0.5


def test_gce_rate_limited_backoff_path():
    """A rate-limited creation (injected runner) must grow the server's
    exponential backoff instead of crashing or retrying immediately."""
    from repro.core.engine import RateLimited
    from repro.core.scheduler import CreateInstance

    def limited_runner(cmd):
        if cmd[3] == "create":
            raise RateLimited("quota")
        return ""

    eng = GCEEngine(GCE_CONFIG, runner=limited_runner)
    srv = Server([], eng, ServerConfig(use_backup=False,
                                       create_backoff_init=0.5,
                                       create_backoff_max=4.0),
                 _internal=True)
    waits = []
    for i in range(5):
        srv._execute_create(CreateInstance("client", f"c{i}"), now=0.0)
        waits.append(srv._next_create_at)
    assert waits == [1.0, 2.0, 4.0, 4.0, 4.0]   # doubling, capped
    assert eng.pending == {}                     # nothing registered

    # a successful creation resets the backoff
    ok = GCEEngine(GCE_CONFIG, runner=lambda c: "")
    srv2 = Server([], ok, ServerConfig(use_backup=False,
                                       create_backoff_init=0.5),
                  _internal=True)
    srv2._backoff = 8.0
    srv2._execute_create(CreateInstance("client", "c0"), now=0.0)
    assert srv2._backoff == 0.5 and "c0" in ok.pending


def test_gce_engine_context_manager_reaps_open_instances():
    calls = []
    with GCEEngine(GCE_CONFIG, runner=lambda c: calls.append(c) or "") \
            as eng:
        eng.create_instance("client", "c0")
        eng.create_instance("client", "c1")
        eng.terminate_instance("c0")
    deletes = [c for c in calls if c[3] == "delete"]
    assert len(deletes) == 2          # c0 explicitly + c1 via shutdown
    assert all(rec[4] is not None for rec in eng.billing_records())


class SleepTask(SimTask):
    """Module-level so it pickles across the worker-process boundary."""

    def run(self):
        time.sleep(0.2)
        return self._result


def test_local_engine_context_manager_reaps_on_error_path():
    """An exception between create_instance and shutdown() must not leak
    the client process (group) — the with-block is the backstop."""
    engine = LocalEngine(n_workers_per_client=1)
    with pytest.raises(RuntimeError, match="boom"), engine:
        engine.create_instance("client", "c0")
        proc = engine._procs["c0"]
        for _ in range(100):
            if proc.is_alive():
                break
            time.sleep(0.05)
        raise RuntimeError("boom")
    deadline = time.time() + 10
    while proc.is_alive() and time.time() < deadline:
        time.sleep(0.05)
    assert not proc.is_alive()
    assert engine.list_instances() == []
    # idempotent: a second shutdown (or exit) is a no-op
    engine.shutdown()


def test_local_engine_end_to_end():
    tasks = [SleepTask((i, 0), ("n", "id"), (i,), 0.0, None, (i,))
             for i in range(1, 7)]
    engine = LocalEngine(n_workers_per_client=2)
    srv = Server(tasks, engine,
                 ServerConfig(max_clients=2, use_backup=False,
                              health_update_limit=30.0))
    table = srv.run(poll_sleep=0.05)
    engine.shutdown()
    assert sorted(p[0] for p, r, s in table.rows if r is not None) == \
        list(range(1, 7))


# ---------------------------------------------------------------------------
# the paper's example workload
# ---------------------------------------------------------------------------
def test_bnb_variants_agree_on_optimum():
    from agent_assignment import Option, bnb_search, generate_instance

    for n_agents, n_tasks in [(4, 3), (5, 4), (6, 5)]:
        t = generate_instance(n_agents, n_tasks, 0)
        brute, _ = bnb_search(t, frozenset({Option.NO_CUTOFFS}))
        bnb, n1 = bnb_search(t, frozenset())
        bnbh, n2 = bnb_search(t, frozenset({Option.HEURISTIC}))
        assert brute == bnb == bnbh
        assert n2 <= n1, "heuristic must not expand more nodes"


def test_bnb_heuristic_is_admissible():
    """Lower bound never exceeds the true optimum of the remaining problem
    (checked indirectly: heuristic search returns the exact optimum)."""
    from agent_assignment import Option, bnb_search, generate_instance

    rng = np.random.default_rng(0)
    for trial in range(5):
        n_tasks = int(rng.integers(2, 5))
        n_agents = n_tasks + int(rng.integers(0, 3))
        t = generate_instance(n_agents, n_tasks, trial, seed=trial)
        brute, _ = bnb_search(t, frozenset({Option.NO_CUTOFFS}))
        got, _ = bnb_search(t, frozenset({Option.HEURISTIC}))
        assert got == brute


def test_paper_example_through_simulator():
    from agent_assignment import build_tasks

    tasks = build_tasks(max_n_tasks=6, n_instances_per_setting=2,
                        deadline=2.0)
    cl = SimCluster(tasks, ServerConfig(max_clients=2, use_backup=False),
                    SimParams(client_workers=2))
    srv = cl.run(until=3600)
    rows = srv.final_results.rows
    assert all(s in ("done", "timed_out", "pruned") for _, _, s in rows)
    solved = [p for p, r, s in rows if s == "done"]
    assert len(solved) > 0
    # the brute-force variant must never solve a larger n_tasks than bnb+h
    max_brute = max((p[1] for p in solved if p[0] == "brute"), default=0)
    max_h = max((p[1] for p in solved if p[0] == "bnb+h"), default=0)
    assert max_h >= max_brute
