"""Engine contract tests (GCE/TPU command construction against a fake
runner; LocalEngine end-to-end) + the paper's B&B example correctness."""
import sys
import time

import numpy as np
import pytest

sys.path.insert(0, "examples")

from repro.core.engine import GCEEngine, TPUPodEngine, LocalEngine
from repro.core.server import Server, ServerConfig
from repro.core.sim import SimCluster, SimParams, SimTask


GCE_CONFIG = {
    "prefix": "agent-assignment",
    "project": "bnb-agent-assignment",
    "zone": "us-central1-a",
    "server_image": "server-template",
    "client_image": "client-template",
    "root_folder": "~/ExpoCloud",
    "project_folder": "examples.agent_assignment",
}


def test_gce_engine_command_contract():
    calls = []

    def fake_runner(cmd):
        calls.append(cmd)
        if cmd[2] == "instances" and cmd[3] == "list":
            return "agent-assignment-client-0\nagent-assignment-client-1\n"
        return ""

    eng = GCEEngine(GCE_CONFIG, runner=fake_runner)
    eng.create_instance("client", "client-0")
    eng.create_instance("backup", "backup-0")
    assert eng.list_instances() == ["client-0", "client-1"]
    eng.terminate_instance("client-0")
    create, backup_create, lst, delete = calls
    assert create[:4] == ["gcloud", "compute", "instances", "create"]
    assert "agent-assignment-client-0" in create
    assert "--source-machine-image=client-template" in create
    assert "--source-machine-image=server-template" in backup_create
    assert "--zone=us-central1-a" in create
    assert delete[3] == "delete" and "--quiet" in delete


def test_gce_engine_rejects_missing_keys():
    with pytest.raises(ValueError, match="missing keys"):
        GCEEngine({"prefix": "x"})


def test_tpu_pod_engine_uses_queued_resources():
    calls = []
    eng = TPUPodEngine(dict(GCE_CONFIG, accelerator_type="v5litepod-256"),
                       runner=lambda c: calls.append(c) or "")
    eng.create_instance("client", "pod-0")
    cmd = calls[0]
    assert cmd[2:5] == ["tpus", "queued-resources", "create"]
    assert "--accelerator-type=v5litepod-256" in cmd


class SleepTask(SimTask):
    """Module-level so it pickles across the worker-process boundary."""

    def run(self):
        time.sleep(0.2)
        return self._result


def test_local_engine_end_to_end():
    tasks = [SleepTask((i, 0), ("n", "id"), (i,), 0.0, None, (i,))
             for i in range(1, 7)]
    engine = LocalEngine(n_workers_per_client=2)
    srv = Server(tasks, engine,
                 ServerConfig(max_clients=2, use_backup=False,
                              health_update_limit=30.0))
    table = srv.run(poll_sleep=0.05)
    engine.shutdown()
    assert sorted(p[0] for p, r, s in table.rows if r is not None) == \
        list(range(1, 7))


# ---------------------------------------------------------------------------
# the paper's example workload
# ---------------------------------------------------------------------------
def test_bnb_variants_agree_on_optimum():
    from agent_assignment import Option, bnb_search, generate_instance

    for n_agents, n_tasks in [(4, 3), (5, 4), (6, 5)]:
        t = generate_instance(n_agents, n_tasks, 0)
        brute, _ = bnb_search(t, frozenset({Option.NO_CUTOFFS}))
        bnb, n1 = bnb_search(t, frozenset())
        bnbh, n2 = bnb_search(t, frozenset({Option.HEURISTIC}))
        assert brute == bnb == bnbh
        assert n2 <= n1, "heuristic must not expand more nodes"


def test_bnb_heuristic_is_admissible():
    """Lower bound never exceeds the true optimum of the remaining problem
    (checked indirectly: heuristic search returns the exact optimum)."""
    from agent_assignment import Option, bnb_search, generate_instance

    rng = np.random.default_rng(0)
    for trial in range(5):
        n_tasks = int(rng.integers(2, 5))
        n_agents = n_tasks + int(rng.integers(0, 3))
        t = generate_instance(n_agents, n_tasks, trial, seed=trial)
        brute, _ = bnb_search(t, frozenset({Option.NO_CUTOFFS}))
        got, _ = bnb_search(t, frozenset({Option.HEURISTIC}))
        assert got == brute


def test_paper_example_through_simulator():
    from agent_assignment import build_tasks

    tasks = build_tasks(max_n_tasks=6, n_instances_per_setting=2,
                        deadline=2.0)
    cl = SimCluster(tasks, ServerConfig(max_clients=2, use_backup=False),
                    SimParams(client_workers=2))
    srv = cl.run(until=3600)
    rows = srv.final_results.rows
    assert all(s in ("done", "timed_out", "pruned") for _, _, s in rows)
    solved = [p for p, r, s in rows if s == "done"]
    assert len(solved) > 0
    # the brute-force variant must never solve a larger n_tasks than bnb+h
    max_brute = max((p[1] for p in solved if p[0] == "brute"), default=0)
    max_h = max((p[1] for p in solved if p[0] == "bnb+h"), default=0)
    assert max_h >= max_brute
