"""End-to-end behaviour tests for the paper's system: ExpoCloud orchestrates
real (subprocess) dry-run cells with hardness pruning — the full bridge from
the paper's scheduler down to XLA compiles — plus the aggregate pipeline."""
import json
import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_expocloud_drives_real_dryrun_cells(tmp_path):
    """Two real cells through LocalEngine: results land in the table and
    the JSON records are written by the worker subprocesses."""
    from repro.core.engine import LocalEngine
    from repro.core.server import Server, ServerConfig
    from repro.core.sweep import DryRunCellTask

    out = str(tmp_path)
    tasks = [
        DryRunCellTask("smollm-360m", "train_4k", "single",
                       seg_counts=(2,), variant={"unroll": 1},
                       deadline=500, out_dir=out, devices=8,
                       mesh_shape=(2, 4), mesh_axes=("data", "model")),
        DryRunCellTask("mamba2-130m", "decode_32k", "single",
                       seg_counts=(2,), variant={"unroll": 1},
                       deadline=500, out_dir=out, devices=8,
                       mesh_shape=(2, 4), mesh_axes=("data", "model")),
    ]
    engine = LocalEngine(n_workers_per_client=1)
    srv = Server(tasks, engine,
                 ServerConfig(max_clients=1, use_backup=False,
                              health_update_limit=300.0,
                              instance_max_non_active_time=300.0))
    table = srv.run(poll_sleep=0.2)
    engine.shutdown()
    assert all(s == "done" for _, _, s in table.rows), table.rows
    for _params, result, _status in table.rows:
        assert result[0] == "ok"
        assert result[1] in ("compute", "memory", "collective")
        assert os.path.exists(result[-1])  # json record path


def test_aggregate_pipeline_on_synthetic_records(tmp_path):
    """assemble() extrapolates probe records into a roofline row."""
    from repro.launch.aggregate import assemble

    def rec(counts, flops, byts, coll):
        return {
            "status": "ok", "compile_s": 1.0,
            "bytes_per_device_inputs": 1e9,
            "memory_analysis": "CompiledMemoryStats()",
            "roofline": {
                "chips": 256, "hlo_flops": flops, "hlo_bytes": byts,
                "collective_bytes_per_chip": coll,
            },
        }

    # smollm-360m: 32 layers, base (2,), bump (3,)
    names = {
        "smollm-360m__train_4k__single__L2_unroll-1.json":
            rec((2,), 10e12, 8e9, 1e6),
        "smollm-360m__train_4k__single__L3_unroll-1.json":
            rec((3,), 13e12, 9e9, 1.5e6),
        "smollm-360m__train_4k__single__full.json": rec(None, 1, 1, 1),
    }
    for name, r in names.items():
        with open(tmp_path / name, "w") as f:
            json.dump(r, f)
    rows = assemble(str(tmp_path))
    row = next(r for r in rows
               if r["arch"] == "smollm-360m" and r["shape"] == "train_4k")
    # extrapolated: 10e12 + 3e12 * (32-2) = 100e12
    assert abs(row["hlo_flops"] - 100e12) / 100e12 < 1e-6
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["status"] == "ok"
