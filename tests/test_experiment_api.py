"""Unified experiment API: ParamSpace/@task declarative grids, the
engines registry, the Experiment facade + streaming RunHandle, and the
facade-vs-hand-wired equivalence regression."""
import pickle

import pytest

from repro.core import engines
from repro.core.experiment import (Experiment, InstanceCreated,
                                   InstancePreempted, Partition, RunDone,
                                   SpotWave, TaskPruned, TaskSolved,
                                   TaskTimedOut)
from repro.core.server import Server, ServerConfig
from repro.core.sim import InstanceType, SimCluster, SimParams, SimTask
from repro.core.space import ParamSpace, axis, task

QUICKSTART_PARAMS = dict(
    client_workers=1, latency_jitter=0.002, seed=0,
    instance_types={"client": InstanceType(creation_delay=1.0,
                                           cost_per_instance_second=2.0)})


# module-level @task functions: picklable by reference (backup snapshots,
# LocalEngine workers)
@task(result_titles=("n_squared",), timeout=3.0,
      sim_duration=lambda n, **_: 0.4 * n)
def square(n, id):
    return (n * n,)


@task(sim_duration=0.1)
def scalar_result(n):
    return n + 1          # scalar return is wrapped into a 1-tuple


def quickstart_space():
    return ParamSpace.grid(n=axis(range(1, 11), hardness="asc"), id=[0])


def quickstart_sim_tasks():
    return [SimTask((n, 0), ("n", "id"), (n,), sim_duration=0.4 * n,
                    deadline=3.0, result=(n * n,)) for n in range(1, 11)]


# ---------------------------------------------------------------------------
# ParamSpace
# ---------------------------------------------------------------------------
def test_grid_cells_declaration_order():
    space = ParamSpace.grid(a=[1, 2], b=["x", "y"])
    assert space.names == ("a", "b")
    assert space.cells() == [
        {"a": 1, "b": "x"}, {"a": 1, "b": "y"},
        {"a": 2, "b": "x"}, {"a": 2, "b": "y"}]
    assert len(space) == 4


def test_grid_scalar_and_range_axes():
    space = ParamSpace.grid(n=range(3), tag="fixed")
    assert all(c["tag"] == "fixed" for c in space)
    assert [c["n"] for c in space] == [0, 1, 2]


def test_dependent_axis_domain():
    space = ParamSpace.grid(
        n=axis(range(2, 5), hardness="asc"),
        m=axis(lambda c: range(c["n"], 5), hardness="asc"))
    cells = space.cells()
    assert all(c["m"] >= c["n"] for c in cells)
    assert len(cells) == 3 + 2 + 1


def test_conditional_axis_gates_and_defaults():
    space = ParamSpace.grid(
        alg=["plain", "tuned"],
        lr=axis([0.1, 0.2], when=lambda c: c["alg"] == "tuned",
                default=None))
    cells = space.cells()
    assert {"alg": "plain", "lr": None} in cells
    assert len([c for c in cells if c["alg"] == "tuned"]) == 2
    assert len(cells) == 3


def test_hardness_directions():
    space = ParamSpace.grid(
        n=axis([4, 8], hardness="asc"),
        cutoff=axis([1, 2], hardness="desc"),
        variant=axis(["easy", "hard"],
                     hardness=lambda v: {"easy": 0, "hard": 9}[v]))
    assert space.hardness_titles() == ("n", "cutoff", "variant")
    assert space.hardness_of({"n": 8, "cutoff": 1, "variant": "hard"}) \
        == (8, -1, 9)
    # non-numeric asc falls back to domain rank
    space2 = ParamSpace.grid(s=axis(["lo", "hi"], hardness="asc"))
    assert space2.hardness_of({"s": "hi"}) == (1,)


def test_bad_hardness_direction_rejected():
    with pytest.raises(ValueError, match="hardness"):
        axis([1, 2], hardness="sideways")


def test_rank_hardness_on_dependent_string_domain_rejected():
    """Cell-relative ranks would make the same value differently hard in
    different cells — the partial order must stay globally consistent."""
    space = ParamSpace.grid(
        n=axis([1, 2], hardness="asc"),
        size=axis(lambda c: ["s", "m", "l"] if c["n"] == 1 else ["m", "l"],
                  hardness="asc"))
    with pytest.raises(ValueError, match="ambiguous"):
        space.hardness_of({"n": 2, "size": "m"})
    # numeric dependent domains are fine (the value itself is the rank)
    ok = ParamSpace.grid(
        n=axis([1, 2], hardness="asc"),
        m=axis(lambda c: range(c["n"], 3), hardness="asc"))
    assert ok.hardness_of({"n": 2, "m": 2}) == (2, 2)


# ---------------------------------------------------------------------------
# @task -> AbstractTask materialization
# ---------------------------------------------------------------------------
def test_task_materialization():
    tasks = quickstart_space().bind(square).tasks()
    assert len(tasks) == 10
    t = tasks[2]
    assert t.parameter_titles() == ("n", "id")
    assert t.parameters() == (3, 0)
    assert t.result_titles() == ("n_squared",)
    assert t.hardness_parameters() == (3,)
    assert t.timeout() == 3.0
    assert t.sim_duration == pytest.approx(1.2)
    assert t.run() == (9,)
    assert t.group_parameter_titles() == ("n",)   # "id" filtered by default


def test_task_timeout_override_and_scalar_wrap():
    space = ParamSpace.grid(n=axis([1, 2], hardness="asc"))
    tasks = space.bind(scalar_result).tasks(timeout=7.5)
    assert tasks[0].timeout() == 7.5
    assert tasks[0].run() == (2,)
    assert tasks[0].result_titles() == ("value",)


def test_timeout_without_hardness_rejected():
    space = ParamSpace.grid(n=[1, 2])      # no hardness axis anywhere
    with pytest.raises(ValueError, match="hardness"):
        space.bind(scalar_result).tasks(timeout=1.0)


def test_unbound_space_rejected():
    with pytest.raises(ValueError, match="unbound"):
        ParamSpace.grid(n=[1]).tasks()


def test_function_tasks_pickle_by_reference():
    t = quickstart_space().bind(square).tasks()[4]
    t2 = pickle.loads(pickle.dumps(t))
    assert t2.parameters() == t.parameters()
    assert t2.run() == t.run()


# ---------------------------------------------------------------------------
# engines registry
# ---------------------------------------------------------------------------
def test_engines_registry_sim_and_unknown():
    spec = engines.make("sim", client_workers=2, seed=7)
    assert isinstance(spec, engines.SimSpec)
    assert spec.params.client_workers == 2 and spec.params.seed == 7
    with pytest.raises(ValueError, match="unknown engine"):
        engines.make("k8s")
    assert {"sim", "local", "gce", "tpu"} <= set(engines.names())


def test_engines_registry_params_xor_kwargs():
    with pytest.raises(ValueError, match="not both"):
        engines.make("sim", params=SimParams(), seed=1)


def test_engines_registry_custom_registration():
    made = {}

    def factory(**cfg):
        made.update(cfg)
        return engines.SimSpec(SimParams())

    engines.register("mycloud", factory)
    try:
        spec = engines.make("mycloud", region="eu")
        assert isinstance(spec, engines.SimSpec) and made == {"region": "eu"}
    finally:
        engines._REGISTRY.pop("mycloud", None)


# ---------------------------------------------------------------------------
# the facade: equivalence regression (acceptance criterion)
# ---------------------------------------------------------------------------
def _hand_wired_quickstart_rows():
    with pytest.warns(DeprecationWarning, match="Experiment"):
        cl = SimCluster(quickstart_sim_tasks(),
                        ServerConfig(max_clients=2, use_backup=False),
                        SimParams(**QUICKSTART_PARAMS))
    cl.spot_wave(5.0, 0.5)
    return cl.run(until=600).final_results


def test_facade_row_identical_to_hand_wired_simcluster():
    """The Experiment facade must produce a results table row-identical to
    the hand-wired SimCluster path — both from a raw task list and from
    the declarative ParamSpace/@task route."""
    expected = _hand_wired_quickstart_rows()

    h1 = Experiment(quickstart_sim_tasks(), engine="sim", max_clients=2,
                    sim=dict(QUICKSTART_PARAMS),
                    chaos=[SpotWave(at=5.0, fraction=0.5)]).run()
    assert h1.results(until=600).rows == expected.rows

    h2 = Experiment(quickstart_space().bind(square), engine="sim",
                    max_clients=2, sim=dict(QUICKSTART_PARAMS),
                    chaos=[SpotWave(at=5.0, fraction=0.5)]).run()
    t2 = h2.results(until=600)
    assert t2.rows == expected.rows
    assert t2.cost["total"] == expected.cost["total"]


def test_old_constructors_still_work_but_warn():
    with pytest.warns(DeprecationWarning, match="Experiment"):
        cl = SimCluster([], ServerConfig(use_backup=False))
    assert cl.server is not None
    with pytest.warns(DeprecationWarning, match="Experiment"):
        Server([], cl.engine, ServerConfig(use_backup=False))


# ---------------------------------------------------------------------------
# RunHandle: typed event stream
# ---------------------------------------------------------------------------
def test_run_handle_streams_typed_events():
    exp = Experiment(quickstart_space().bind(square), engine="sim",
                     max_clients=2, sim=dict(QUICKSTART_PARAMS),
                     chaos=[SpotWave(at=5.0, fraction=0.5)])
    with exp.run() as run:
        evs = list(run.events(until=600))
        table = run.results()
    done = evs[-1]
    assert isinstance(done, RunDone)
    solved_rows = [p for p, r, _ in table.rows if r is not None]
    solved_evs = [e for e in evs if isinstance(e, TaskSolved)]
    assert len(solved_evs) == len(solved_rows) == done.solved
    # event payloads carry the cell parameters + result
    assert sorted(e.params[0] for e in solved_evs) \
        == sorted(p[0] for p in solved_rows)
    assert any(isinstance(e, TaskTimedOut) for e in evs)
    assert any(isinstance(e, TaskPruned) for e in evs)
    assert any(isinstance(e, InstanceCreated) for e in evs)
    # the spot wave kills half the fleet -> preemption events
    assert any(isinstance(e, InstancePreempted) for e in evs)
    assert done.cost == table.cost["total"]
    # event times are monotone
    ts = [e.t for e in evs]
    assert ts == sorted(ts)


def test_chaos_partition_directive_and_callable():
    calls = []
    exp = Experiment(
        [SimTask((1, 0), ("n", "id"), (1,), 0.5, None, (1,))],
        engine="sim", max_clients=1,
        chaos=[Partition("primary", "client-0", at=100.0, until=101.0),
               lambda cl: calls.append(cl)])
    with exp.run() as run:
        run.results(until=600)
    assert len(calls) == 1 and isinstance(calls[0], SimCluster)


def test_chaos_requires_sim_engine():
    with pytest.raises(ValueError, match="chaos"):
        Experiment([], engine="local", chaos=[SpotWave(1.0, 0.5)])
    # a custom registered name is validated against the *resolved* spec
    engines.register("realish", lambda **c: engines.make("local", **c))
    try:
        h = Experiment([], engine="realish",
                       chaos=[SpotWave(1.0, 0.5)]).run()
        with pytest.raises(ValueError, match="chaos"):
            h.engine  # noqa: B018 — property triggers lazy start
    finally:
        engines._REGISTRY.pop("realish", None)


def test_chaos_allowed_on_registered_sim_backed_engine():
    engines.register("simish",
                     lambda **c: engines.SimSpec(SimParams(**c)))
    try:
        exp = Experiment(
            [SimTask((1, 0), ("n", "id"), (1,), 0.5, None, (1,))],
            engine="simish", max_clients=1,
            chaos=[SpotWave(at=100.0, fraction=0.5)])
        assert exp.run().results(until=600).rows
    finally:
        engines._REGISTRY.pop("simish", None)


def test_unknown_server_config_field_rejected():
    with pytest.raises(ValueError, match="ServerConfig"):
        Experiment([], engine="sim", max_cleints=3)


def test_config_conflicts_with_convenience_params():
    with pytest.raises(ValueError, match="not both"):
        Experiment([], engine="sim", config=ServerConfig(),
                   budget_cap=100.0)
    with pytest.raises(ValueError, match="not both"):
        Experiment([], engine="sim", config=ServerConfig(),
                   min_group_size=2)


@pytest.mark.parametrize("values,direction,default", [
    (["hi", "mid", "lo"], "desc", "off"),    # ranked
    (["lo", "hi"], "asc", "off"),
    ([10, 20], "desc", 0),                   # numeric fast path
    ([-5, -2], "asc", 0),                    # negative numeric domain
    (["easy", "hard"],                       # callable never sees default
     lambda v: {"easy": 0, "hard": 9}[v], None),
])
def test_conditional_default_ranks_easiest(values, direction, default):
    space = ParamSpace.grid(
        on=[False, True],
        lvl=axis(values, hardness=direction,
                 when=lambda c: c["on"], default=default))
    declared = [space.hardness_of({"on": True, "lvl": v})[0]
                for v in values]
    fallback = space.hardness_of({"on": False, "lvl": default})[0]
    assert fallback < min(declared)          # easiest, never hardest


# ---------------------------------------------------------------------------
# snapshot / resume
# ---------------------------------------------------------------------------
def test_resume_from_snapshot_completes_the_run():
    space = quickstart_space().bind(square)
    exp = Experiment(space, engine="sim", max_clients=2,
                     sim=dict(QUICKSTART_PARAMS))
    h = exp.run()
    for _ in range(500):
        h.cluster.step()
        if sum(1 for s in h.server.core.status if s == "done") >= 2:
            break
    partial = sum(1 for s in h.server.core.status if s == "done")
    assert 0 < partial < 10
    blob = h.snapshot()

    h2 = Experiment(space, engine="sim", max_clients=2,
                    sim=dict(QUICKSTART_PARAMS)).resume(blob)
    table = h2.results(until=3600)
    # every task is accounted for; the solved prefix is preserved
    assert len(table.rows) == 10
    statuses = {s for _, _, s in table.rows}
    assert statuses <= {"done", "timed_out", "pruned"}
    assert sum(1 for _, r, _ in table.rows if r is not None) >= partial


def test_resume_of_finished_snapshot_is_stable():
    exp = Experiment(quickstart_space().bind(square), engine="sim",
                     max_clients=2, sim=dict(QUICKSTART_PARAMS))
    h = exp.run()
    table = h.results(until=600)
    h2 = exp.resume(h.snapshot())
    assert h2.results(until=600).rows == table.rows


# ---------------------------------------------------------------------------
# with-scoped shutdown
# ---------------------------------------------------------------------------
def test_abandoned_real_event_stream_fails_results_loudly():
    """Breaking out of events() on a real engine shuts the fleet down; a
    later results() must raise instead of hanging on a dead fleet."""
    from repro.core.engine import LocalEngine

    class NeverEngine(LocalEngine):
        def __init__(self):
            # skip process machinery: no instance ever handshakes, so the
            # stream never sees RunDone and we can abandon it mid-run.
            # One pre-seeded billing record makes the watcher emit an
            # InstanceCreated event for the loop body to break on.
            self.pending = {}
            self._procs = {}
            self._kinds = {}
            self._billing = {"ghost": ["client", 1.0, 0.0, None]}
            self._mgr = None

        def create_instance(self, kind, name, payload=None):
            pass

        class _Quiet:
            def poll(self):
                return None
        handshake_recv = _Quiet()

        def shutdown(self):
            self.was_shut = True

    eng = NeverEngine()
    h = Experiment([SimTask((1, 0), ("n", "id"), (1,), 0.1, None, (1,))],
                   engine=eng, max_clients=1).run()
    for _ in h.events(poll_sleep=0.0):
        break                       # no events come; generator closed
    assert eng.was_shut
    with pytest.raises(RuntimeError, match="abandoned"):
        h.results()
    # results() wall-clock bound on real engines raises, never hangs
    h2 = Experiment([SimTask((1, 0), ("n", "id"), (1,), 0.1, None, (1,))],
                    engine=NeverEngine(), max_clients=1).run()
    with pytest.raises(TimeoutError):
        h2.results(until=0.2, poll_sleep=0.0)


def test_run_handle_closes_engine_on_exit():
    closed = []

    exp = Experiment([SimTask((1, 0), ("n", "id"), (1,), 0.1, None, (1,))],
                     engine="sim", max_clients=1)
    with exp.run() as run:
        run.results(until=100)
        run.engine.shutdown = lambda: closed.append(True)
    assert closed == [True]
    run.shutdown()                       # idempotent
    assert closed == [True]
